"""System + Session: the one front door over every SLED execution backend.

``System.build(spec)`` turns a :class:`~repro.api.spec.ServeSpec` into a
running deployment — it builds the model pair once, constructs the backend
the spec names (lock-step reference loop, in-process ServerEngine, replica
Router, or the asyncio transport runtime), owns warmup and the shared jitted
:class:`~repro.core.engine.VerifySteps` bundle, and hands out sessions:

    spec = ServeSpec(backend="engine", devices=2, max_new=16)
    system = System.build(spec)
    session = system.open_session()
    for ev in session.generate():      # TokenEvent / RoundEvent / DoneEvent
        ...
    session.result                     # unified SessionResult

``system.serve()`` runs the spec's whole default fleet concurrently and
returns a :class:`~repro.api.events.ServeResult` (per-session results plus
merged EngineStats/ClientStats) — that is what launch/serve.py and the
benchmarks drive.  All four backends commit token-identical streams for the
same spec under greedy drafting on lossless links; the cross-backend
equivalence test (tests/test_api.py) and the CI api-smoke job hold that
line.

Sessions on the in-process backends interleave cooperatively: each
``generate()`` pump admits waiting sessions, submits ready drafts, and steps
the engine once, so concurrently-pumped sessions batch together exactly as
the raw driver loops did.  Transport sessions run the real asyncio client
under the hood (a dedicated loop thread when a single session is streamed).
"""

from __future__ import annotations

import asyncio
import dataclasses
import logging
import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterator, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.api.events import (
    DoneEvent,
    Event,
    RoundEvent,
    ServeResult,
    SessionResult,
    TokenEvent,
)
from repro.api.spec import FaultSpec, ServeSpec
from repro.cluster import Router
from repro.configs.base import get_config
from repro.core import engine_loop
from repro.core.engine import EngineStats
from repro.core.server_engine import EdgeDeviceKit, ServerEngine
from repro.models.kvcache import supports_paged_attention
from repro.models.model_zoo import build_model, perturb_params
from repro.quant.quantize import dequantize_pytree, quantize_pytree
from repro.serving.devices import NETS
from repro.transport import codec
from repro.transport.client import ClientStats, EdgeClient
from repro.transport.links import make_link
from repro.transport.server import TransportServer

log = logging.getLogger(__name__)

_ENGINE_BACKENDS = ("engine", "cluster", "transport")


# ---------------------------------------------------------------------------
# model construction (shared by every backend)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModelBundle:
    """The built draft/target pair for one ModelSpec — reusable across
    Systems so a spec sweep pays model init once."""

    target_cfg: Any
    draft_cfg: Any
    target: Any
    draft: Any
    target_params: Any
    draft_params: Any

    @property
    def vocab(self) -> int:
        return self.target_cfg.vocab_size


def build_models(mspec) -> ModelBundle:
    """Deterministically build the spec's reduced model pair: target from
    ``key(seed)`` (optionally weight-quantized), draft from ``key(seed+1)``
    (optionally noise-perturbed so greedy acceptance is non-trivial)."""
    tcfg = dataclasses.replace(get_config(mspec.arch).reduced(), vocab_size=mspec.vocab_size)
    if mspec.target_layers is not None:
        tcfg = dataclasses.replace(tcfg, num_layers=mspec.target_layers)
    dcfg = dataclasses.replace(
        get_config(mspec.draft_arch).reduced(), name="edge-draft", vocab_size=mspec.vocab_size
    )
    if mspec.draft_layers is not None:
        dcfg = dataclasses.replace(dcfg, num_layers=mspec.draft_layers)
    target, draft = build_model(tcfg), build_model(dcfg)
    kw = {"max_pos": 256} if not tcfg.use_rope else {}
    tp = target.init_params(jax.random.key(mspec.seed), **kw)
    if mspec.bits < 16:
        tp = dequantize_pytree(quantize_pytree(tp, mspec.bits))
    dp = perturb_params(draft.init_params(jax.random.key(mspec.seed + 1)), mspec.draft_noise)
    return ModelBundle(tcfg, dcfg, target, draft, tp, dp)


def build_draft_variant(mspec, *, draft_layers: Optional[int], draft_noise: float):
    """One device class's draft bundle: the spec's draft arch/vocab/seed with
    overridden depth and perturbation noise.  Deterministic — params come
    from ``key(seed+1)`` exactly like :func:`build_models`, so a class whose
    overrides equal the spec model's reproduces ``models.draft_params``
    bit-for-bit (System.build just reuses the shared bundle there)."""
    dcfg = dataclasses.replace(
        get_config(mspec.draft_arch).reduced(), name="edge-draft", vocab_size=mspec.vocab_size
    )
    if draft_layers is not None:
        dcfg = dataclasses.replace(dcfg, num_layers=draft_layers)
    draft = build_model(dcfg)
    dp = perturb_params(draft.init_params(jax.random.key(mspec.seed + 1)), draft_noise)
    return dcfg, draft, dp


class KitCache:
    """Shared per-class draft weights + jitted drafting kits for spec sweeps.

    Tuner candidates that agree on a class's draft config (arch, layers,
    noise, vocab, seed) reuse the built params; candidates that also agree
    on the kit knobs (k, c_th, greedy, attn_chunk) reuse the compiled
    EdgeDeviceKit — a sweep over fleet candidates pays each distinct draft
    build and device-side compile once instead of once per System."""

    def __init__(self) -> None:
        self.drafts: Dict[tuple, tuple] = {}  # draft key -> (cfg, model, params)
        self.kits: Dict[tuple, EdgeDeviceKit] = {}


# ---------------------------------------------------------------------------
# sessions
# ---------------------------------------------------------------------------


class Session:
    """One device's stream against a System backend.

    ``generate()`` yields typed events (TokenEvent* RoundEvent ... DoneEvent)
    and leaves the unified :class:`SessionResult` in ``.result``; ``run()``
    drains the generator and returns the result directly.
    """

    def __init__(
        self,
        system: "System",
        device_id: int,
        prompt: np.ndarray,
        max_new: int,
        join_tick: int = 0,
    ):
        self._system = system
        self.device_id = device_id
        self.prompt = np.asarray(prompt, np.int32)
        self.max_new = max_new
        self.join_tick = join_tick
        self.result: Optional[SessionResult] = None
        self._events: deque = deque()
        self._sink: Optional[Callable[[Event], None]] = None
        self._device = None  # EdgeDevice once admitted (in-process backends)
        self._last_drafted = 0
        self._rounds = 0
        self._drafted = 0
        self._accepted = 0
        self._fallback_rounds = 0
        self._fallback_tokens = 0
        self._committed = 0
        self._t_open = time.time()
        self._trace: List = []  # per-round TraceEvents (telemetry on)

    @property
    def done(self) -> bool:
        return self.result is not None

    def generate(self) -> Iterator[Event]:
        return self._system._generate(self)

    def run(self) -> SessionResult:
        for _ in self.generate():
            pass
        return self.result

    # -- event plumbing (driven by the System backends) ----------------------

    def _push(self, ev: Event) -> None:
        if self._sink is not None:
            self._sink(ev)
        else:
            self._events.append(ev)

    def _note_round(
        self,
        tokens: np.ndarray,
        n_drafted: int,
        n_accepted: int,
        fallback: bool = False,
    ) -> None:
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        for t in toks:
            if self._committed < self.max_new:
                self._push(TokenEvent(self.device_id, t, self._committed))
            self._committed += 1
        self._push(
            RoundEvent(
                device_id=self.device_id,
                round=self._rounds,
                n_drafted=int(n_drafted),
                n_accepted=int(n_accepted),
                tokens=tuple(toks),
                fallback=fallback,
            )
        )
        self._rounds += 1
        self._drafted += int(n_drafted)
        if fallback:
            self._fallback_rounds += 1
            self._fallback_tokens += len(toks)
        else:
            self._accepted += int(n_accepted)

    def _finish(
        self,
        tokens,
        client: Optional[ClientStats] = None,
        shed: bool = False,
    ) -> None:
        tokens = [int(t) for t in tokens][: self.max_new]
        self.result = SessionResult(
            device_id=self.device_id,
            tokens=tokens,
            rounds=self._rounds,
            drafted=self._drafted,
            accepted=self._accepted,
            fallback_rounds=self._fallback_rounds,
            fallback_tokens=self._fallback_tokens,
            wall_seconds=(
                client.wall_seconds if client is not None else time.time() - self._t_open
            ),
            shed=shed,
            client=client,
            trace=self._trace,
        )
        self._system._waiting.pop(self.device_id, None)
        self._system._running.pop(self.device_id, None)
        self._push(DoneEvent(self.device_id, len(tokens)))


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------


class System:
    """A built SLED deployment: models + the spec's execution backend."""

    def __init__(
        self,
        spec: ServeSpec,
        models: ModelBundle,
        engine: Union[ServerEngine, Router, None],
        kit: Optional[EdgeDeviceKit],
        class_kits: Optional[List[EdgeDeviceKit]] = None,
    ):
        self.spec = spec
        self.models = models
        self.engine = engine  # ServerEngine | Router | None (reference)
        self.kit = kit
        # fleet backends: one kit per resolved device class (kit_for routes)
        self.class_kits: List[EdgeDeviceKit] = list(class_kits or [])
        self._waiting: Dict[int, Session] = {}
        self._running: Dict[int, Session] = {}
        self._used_ids: set = set()
        self._tick = 0
        self._t0: Optional[float] = None
        self._ref_steps: Optional[dict] = None
        # one transport fleet at a time: the engine below is not thread-safe,
        # and each fleet run owns its own TransportServer + event loop
        self._transport_lock = threading.Lock()

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        spec: ServeSpec,
        *,
        models: Optional[ModelBundle] = None,
        steps=None,
        kit: Optional[EdgeDeviceKit] = None,
        kits: Optional[KitCache] = None,
        warmup: bool = False,
    ) -> "System":
        """Construct the backend the spec names.

        ``models`` / ``steps`` / ``kit`` let spec sweeps share built weights,
        a compiled VerifySteps bundle, and the device-side jitted kit across
        Systems (homogeneous configs only — the engine validates sharing).
        ``kits`` (a :class:`KitCache`) does the same for FLEET sweeps: the
        per-class draft bundles and jitted kits candidates have in common
        are built once and shared across the Systems the sweep constructs.
        """
        spec.validate()
        if spec.telemetry:
            # enable-only: a telemetry spec turns collection on process-wide;
            # it is never flipped back off here, so sweeps that interleave
            # telemetry and plain specs keep collecting (benchmarks that need
            # a clean off-state call telemetry.enable(False) explicitly)
            telemetry.enable(True)
        if spec.backend == "transport" and spec.transport.codec_version != codec.VERSION:
            # the spec layer can DESCRIBE other protocol versions (artifacts
            # shipped between heterogeneous hosts), but this runtime only
            # speaks the current one — refuse rather than silently upgrade
            raise ValueError(
                f"this runtime speaks codec v{codec.VERSION} only; the spec "
                f"declares codec_version={spec.transport.codec_version}"
            )
        if models is not None and spec.cluster.has_remote:
            log.warning(
                "a shared ModelBundle cannot ship to remote workers: each "
                "worker rebuilds params from the spec's model seed, so a "
                "bundle that differs from build_models(spec.model) would "
                "break cross-process token identity"
            )
        models = models or build_models(spec.model)
        fam = getattr(models.target_cfg, "family", None)
        if spec.kv_dtype == "int8" and not supports_paged_attention(models.target_cfg):
            # loud, not a warning: a silently-bf16 pool would report double
            # the capacity the deployment actually has
            raise ValueError(
                f"kv_dtype='int8' is not supported for model family {fam!r} "
                f"({spec.model.arch}): its caches ride the gather/scatter "
                "fallback (models/kvcache.py) whose recurrent state leaves "
                "have no quantized layout — serve this family with "
                "kv_dtype='bf16'"
            )
        if (
            spec.backend in _ENGINE_BACKENDS
            and spec.paged_attention
            and not supports_paged_attention(models.target_cfg)
        ):
            log.warning(
                "paged attention is unavailable for model family %r (%s): "
                "verification falls back to gather/scatter cache paging",
                fam,
                spec.model.arch,
            )
        engine: Union[ServerEngine, Router, None] = None
        if spec.backend in _ENGINE_BACKENDS:
            engine_kw = dict(
                n_slots=spec.slots_per_replica,
                max_len=spec.max_len,
                k_max=spec.k_max,
                policy=spec.scheduler.policy,
                max_wait=spec.scheduler.max_wait,
                straggler_timeout=spec.scheduler.straggler_timeout,
                greedy=spec.greedy,
                attn_chunk=spec.attn_chunk,
                paged_attention=spec.paged_attention,
                kv_dtype=spec.kv_dtype,
                steps=steps,
            )
            if spec.cluster.has_remote:
                engine = cls._build_remote_cluster(spec, models, engine_kw)
            elif spec.backend == "engine" or (
                spec.backend == "transport"
                and spec.cluster.n_replicas == 1
                and not spec.faults.active
            ):
                # single replica: the bare engine (TransportServer fronts a
                # Router or an engine interchangeably); a fault schedule
                # needs the Router's supervision, so chaos runs keep it
                engine = ServerEngine(models.target, models.target_params, **engine_kw)
            else:  # cluster, or transport fronting a replica set
                n_slots = engine_kw.pop("n_slots")
                engine = Router.build(
                    models.target,
                    models.target_params,
                    replicas=spec.cluster.n_replicas,
                    n_slots=n_slots,
                    placement=cls._placement(spec),
                    migrate_on_retire=spec.cluster.migrate_on_retire,
                    faults=spec.cluster.faults,
                    **engine_kw,
                )
            if spec.faults.active and isinstance(engine, Router):
                from repro.cluster.faults import ChaosInjector

                engine.chaos = ChaosInjector(spec.faults, engine)
        kit = kit or EdgeDeviceKit(
            models.draft,
            models.draft_params,
            k_max=spec.k_max,
            c_th=spec.c_th,
            greedy=spec.greedy,
            attn_chunk=spec.attn_chunk,
        )
        class_kits = cls._build_class_kits(spec, models, kits) if spec.fleet.active else None
        system = cls(spec, models, engine, kit, class_kits=class_kits)
        if warmup:
            system.warmup()
        return system

    @classmethod
    def _placement(cls, spec: ServeSpec):
        """The Router placement argument: the spec's policy name, or a
        ClassAffinityPlacement wired to the fleet's device→class map so
        each device class gets a home replica (drafts of one class share
        verify batches — one k, one draft distribution per batch)."""
        if spec.cluster.placement == "class-affinity" and spec.fleet.active:
            from repro.cluster.router import ClassAffinityPlacement

            ranges = tuple((rc.lo, rc.hi) for rc in spec.resolved_classes())

            def class_index(dev: int, _ranges=ranges) -> int:
                for i, (lo, hi) in enumerate(_ranges):
                    if lo <= dev < hi:
                        return i
                return dev  # late-joined id outside the fleet: own bucket

            return ClassAffinityPlacement(class_index)
        return spec.cluster.placement

    @classmethod
    def _build_class_kits(
        cls, spec: ServeSpec, models: ModelBundle, cache: Optional[KitCache]
    ) -> List[EdgeDeviceKit]:
        """One jitted drafting kit per resolved fleet class.  Classes whose
        draft config matches the spec model ride the shared ModelBundle
        (same params object — no rebuild); distinct configs build their own
        deterministic variant.  Identical (draft, k, c_th) classes share
        one kit — and via ``cache`` so do identical classes across sweep
        candidates — so the device-side scan compiles once per distinct
        shape."""
        mspec = spec.model
        cache = cache if cache is not None else KitCache()
        out: List[EdgeDeviceKit] = []
        for rc in spec.resolved_classes():
            dkey = (mspec.draft_arch, rc.draft_layers, rc.draft_noise,
                    mspec.vocab_size, mspec.seed)
            if (rc.draft_layers, rc.draft_noise) == (mspec.draft_layers, mspec.draft_noise):
                bundle = (models.draft_cfg, models.draft, models.draft_params)
            else:
                bundle = cache.drafts.get(dkey)
                if bundle is None:
                    bundle = build_draft_variant(
                        mspec, draft_layers=rc.draft_layers, draft_noise=rc.draft_noise
                    )
                    cache.drafts[dkey] = bundle
            _, dmodel, dparams = bundle
            kkey = dkey + (rc.k, rc.c_th, spec.greedy, spec.attn_chunk)
            kit_c = cache.kits.get(kkey)
            if kit_c is None:
                kit_c = EdgeDeviceKit(
                    dmodel, dparams,
                    k_max=rc.k, c_th=rc.c_th,
                    greedy=spec.greedy, attn_chunk=spec.attn_chunk,
                )
                cache.kits[kkey] = kit_c
            out.append(kit_c)
        return out

    @classmethod
    def _build_remote_cluster(cls, spec: ServeSpec, models, engine_kw) -> Router:
        """Assemble a mixed local/remote Router from the spec's replica list.

        Each remote replica either DIALS a worker you already started (the
        ReplicaSpec names an address) or SPAWNS one on a private unix socket
        (no address; the System reaps it on close()).  The worker is then
        PLACED: it receives this spec reduced to one single-replica engine —
        same model seed, same pool shape — and rebuilds params
        deterministically, which is what keeps a cross-process fleet
        token-identical to the in-process cluster.  Local entries construct
        ServerEngines in this process, sharing one compiled bundle."""
        from repro.cluster import RemoteReplica, spawn_worker
        from repro.cluster.faults import FaultyChannel
        from repro.cluster.remote import DEFAULT_TIMEOUT

        policy = spec.cluster.faults
        rpc_timeout = policy.rpc_timeout_s if policy.rpc_timeout_s > 0 else DEFAULT_TIMEOUT
        # drop/delay/flap chaos events act on the control channel, so remote
        # channels get wrapped whenever the schedule contains one
        wrap_channels = any(
            e.kind in ("drop", "delay", "flap") for e in spec.faults.events
        )
        n_slots_default = engine_kw.pop("n_slots")
        steps = engine_kw.pop("steps", None)
        # the chaos schedule is executed by the ROUTER against its replicas;
        # the spec a worker is placed with must not carry it (and 'engine'
        # backend rejects fault schedules outright)
        worker_base = spec.with_backend("engine", faults=FaultSpec())
        replicas: list = []
        try:
            for rs in spec.cluster.replica_specs:
                slots = rs.slots or n_slots_default
                if rs.flavor == "inproc":
                    local = ServerEngine(
                        models.target, models.target_params,
                        n_slots=slots, steps=steps, **engine_kw,
                    )
                    steps = local.steps  # siblings ride the first compile
                    replicas.append(local)
                    continue
                worker_spec = dataclasses.replace(
                    worker_base,
                    scheduler=dataclasses.replace(worker_base.scheduler, slots=slots),
                )
                if rs.address:
                    remote = RemoteReplica.dial(rs.address, timeout=rpc_timeout)
                else:
                    proc, addr = spawn_worker()
                    remote = RemoteReplica.dial(addr, timeout=rpc_timeout)
                    remote.proc = proc
                    remote.spawned = True
                remote.retry_rpcs = policy.retry_rpcs
                if wrap_channels:
                    remote.channel = FaultyChannel(remote.channel)
                remote.place(worker_spec)
                replicas.append(remote)
        except BaseException:
            for r in replicas:
                if getattr(r, "flavor", "local") == "remote":
                    r.drain()
            raise
        return Router(
            replicas,
            placement=cls._placement(spec),
            migrate_on_retire=spec.cluster.migrate_on_retire,
            faults=policy,
        )

    @property
    def steps(self):
        """The jitted VerifySteps bundle (shareable across homogeneous
        Systems); None for the reference backend and for a fleet whose
        first replica is remote (compiled executables cannot cross
        processes)."""
        if self.engine is None:
            return None
        return self.engine.steps if isinstance(self.engine, ServerEngine) else (
            self.engine.replicas[0].steps
        )

    def close(self) -> None:
        """Release cross-process resources: drain every remote worker (and
        reap the ones this System spawned).  In-process backends are
        no-ops; safe to call twice."""
        if isinstance(self.engine, Router):
            self.engine.drain()

    def __enter__(self) -> "System":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def warmup(self, buckets=None) -> Dict[int, float]:
        """Pre-compile the verify buckets (engine-backed backends only)."""
        if self.engine is None:
            return {}
        return self.engine.warmup(buckets)

    def prompts(self) -> np.ndarray:
        """The spec's default workload: ``(devices, prompt_len)`` prompts."""
        return np.asarray(
            jax.random.randint(
                jax.random.key(self.spec.prompt_seed),
                (self.spec.devices, self.spec.prompt_len),
                0,
                self.models.vocab,
            )
        )

    def kit_for(self, device_id: int) -> EdgeDeviceKit:
        """The jitted drafting kit serving ``device_id`` — its device
        class's kit under a fleet spec, else the homogeneous spec kit."""
        if self.class_kits:
            rc = self.spec.class_of(device_id)
            if rc is not None:
                return self.class_kits[rc.index]
        return self.kit

    def rate_for(self, device_id: int) -> Optional[float]:
        """Draft-rate throttle for ``device_id`` in tokens/s (None means
        unthrottled): the class's measured hardware rate scaled by
        ``fleet.rate_scale`` when the fleet emulates device speeds, else
        the transport-level ``draft_rate``."""
        fleet = self.spec.fleet
        if fleet.active and fleet.emulate_rates:
            rc = self.spec.class_of(device_id)
            if rc is not None:
                return rc.hardware_rate() * fleet.rate_scale
        return self.spec.transport.draft_rate

    # -- sessions ------------------------------------------------------------

    def open_session(
        self,
        prompt=None,
        *,
        device_id: Optional[int] = None,
        max_new: Optional[int] = None,
        join_tick: int = 0,
    ) -> Session:
        """Register a stream; it joins the backend when first pumped."""
        if device_id is None:
            device_id = 0
            while device_id in self._used_ids:
                device_id += 1
        if device_id in self._used_ids:
            raise ValueError(f"device {device_id} already has a session")
        if prompt is None:
            defaults = self.prompts()
            if device_id >= defaults.shape[0]:
                raise ValueError(
                    f"no default prompt for device {device_id} "
                    f"(spec.devices={self.spec.devices}); pass prompt="
                )
            prompt = defaults[device_id]
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        budget = max_new or self.spec.max_new
        if (
            self.engine is not None
            and prompt.shape[0] + budget + self.spec.k_max + 1 > self.spec.max_len
        ):
            raise ValueError(
                f"prompt of {prompt.shape[0]} + max_new {budget} + k_max+1 slack "
                f"exceeds the pool row length max_len={self.spec.max_len}"
            )
        self._used_ids.add(device_id)
        session = Session(
            self,
            device_id,
            prompt,
            budget,
            join_tick=join_tick,
        )
        self._waiting[device_id] = session
        return session

    # -- fleet serve ---------------------------------------------------------

    def serve(
        self,
        prompts=None,
        *,
        max_new: Optional[int] = None,
        on_event: Optional[Callable[[Event], None]] = None,
    ) -> ServeResult:
        """Run the whole fleet (spec workload, or explicit ``prompts``)
        concurrently to completion; the one-call driver behind serve.py and
        the benchmarks.

        A System may serve() repeatedly (the engine and its compiled steps
        stay warm), but engine stats are LIFETIME-cumulative across runs —
        benchmarks that need clean per-run stats build a fresh System sharing
        ``models``/``steps``/``kit`` instead.
        """
        if self._waiting or self._running:
            raise RuntimeError("serve() needs a fresh System (sessions already open)")
        # per-run driver state: clock, stagger ticks, and device-id space —
        # repeated serve() calls reuse ids 0..N-1 (prior streams all retired),
        # so runs are comparable and session seeds stay spec-determined
        self._tick, self._t0 = 0, None
        self._used_ids.clear()
        prompts = self.prompts() if prompts is None else np.asarray(prompts)
        sink = on_event or (lambda ev: None)
        sessions = []
        for i in range(prompts.shape[0]):
            s = self.open_session(
                prompts[i],
                device_id=i if i not in self._used_ids else None,
                max_new=max_new,
                join_tick=i * self.spec.scheduler.stagger_ticks,
            )
            s._sink = sink
            sessions.append(s)
        t0 = time.time()
        clients: Optional[ClientStats] = None
        if self.spec.backend == "reference":
            for _ in self._reference_rounds(sessions):
                pass
            stats = self._reference_stats(sessions, time.time() - t0)
        elif self.spec.backend == "transport":
            with self._transport_lock:
                stats, clients = asyncio.run(self._transport_fleet(sessions))
        else:
            deadline = time.time() + 600.0
            while not all(s.done for s in sessions):
                self._pump_inproc()
                if time.time() > deadline:
                    raise RuntimeError("in-process fleet failed to drain in 600s")
            stats = self.engine.stats(time.time() - (self._t0 or t0))
        payload: Optional[dict] = None
        if telemetry.enabled():
            if self.engine is not None and hasattr(self.engine, "telemetry_payload"):
                payload = self.engine.telemetry_payload()
            else:  # reference backend: registry snapshot, no server flight ring
                payload = {"snapshot": telemetry.registry().snapshot(), "flight": []}
        return ServeResult(
            backend=self.spec.backend,
            sessions=[s.result for s in sessions],
            engine=stats,
            clients=clients,
            wall_seconds=time.time() - t0,
            lost_devices=sorted(getattr(self.engine, "lost_devices", []) or []),
            telemetry=payload,
        )

    # -- single-session streaming --------------------------------------------

    def _generate(self, session: Session) -> Iterator[Event]:
        if session.done:
            yield from ()
            return
        if self.spec.backend == "reference":
            gen = self._reference_rounds([session])
        elif self.spec.backend == "transport":
            yield from self._generate_transport(session)
            return
        else:
            gen = self._pump_driver(session)
        for _ in gen:
            while session._events:
                yield session._events.popleft()
        while session._events:
            yield session._events.popleft()

    def _pump_driver(self, session: Session) -> Iterator[None]:
        deadline = time.time() + 600.0
        while not session.done:
            self._pump_inproc()
            if time.time() > deadline:
                raise RuntimeError(f"session {session.device_id} failed to finish in 600s")
            yield None

    def _generate_transport(self, session: Session) -> Iterator[Event]:
        """Stream one transport session: the asyncio client runs on a
        dedicated loop thread and events cross over a queue.  Concurrent
        transport streams serialize behind the System's transport lock (the
        engine is not thread-safe).  Closing the generator early cancels the
        background run and retires the stream best-effort."""
        q: queue.Queue = queue.Queue()
        session._sink = q.put
        done = object()
        cancelled = threading.Event()
        handle: dict = {}

        def work():
            async def runner():
                handle["loop"] = asyncio.get_running_loop()
                handle["task"] = asyncio.current_task()
                await self._transport_fleet([session])

            with self._transport_lock:
                if cancelled.is_set():  # consumer left before our turn
                    q.put(done)
                    return
                try:
                    asyncio.run(runner())
                except asyncio.CancelledError:
                    pass
                except BaseException as e:  # surfaced on the consumer side
                    q.put(e)
                finally:
                    if not session.done:  # cancelled mid-stream: free the slot
                        self._waiting.pop(session.device_id, None)
                        if self.engine is not None and session.device_id in self.engine.streams:
                            self.engine.retire(session.device_id)
                q.put(done)

        t = threading.Thread(target=work, daemon=True)
        t.start()
        try:
            while True:
                item = q.get()
                if item is done:
                    break
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            cancelled.set()
            if not session.done and handle.get("task") is not None:
                try:
                    handle["loop"].call_soon_threadsafe(handle["task"].cancel)
                except RuntimeError:
                    pass  # loop already closed
            t.join(timeout=60.0)

    # -- in-process backends (engine / cluster) ------------------------------

    def _pump_inproc(self) -> None:
        """One scheduler tick: admit joined sessions, submit ready drafts,
        step the engine, route verdicts back to their sessions.  Pumping from
        several generators interleaves their streams into shared batches."""
        if self.engine is None:
            raise RuntimeError("the reference backend has no engine to pump")
        if self._t0 is None:
            self._t0 = time.time()
        self._tick += 1
        now = time.time() - self._t0
        for dev_id in sorted(self._waiting):
            s = self._waiting[dev_id]
            if s.join_tick > self._tick:
                continue
            if self.engine.admit(dev_id, s.prompt, now) is None:
                break  # pool full: stays waiting, admitted when a slot frees
            s._device = self.kit_for(dev_id).spawn(
                dev_id,
                s.prompt,
                max_len=self.spec.max_len,
                seed=self.spec.session_seed_base + dev_id,
            )
            self._running[dev_id] = s
            del self._waiting[dev_id]
        for s in list(self._running.values()):
            if not s._device.awaiting:
                toks = s._device.draft()
                s._last_drafted = len(toks)
                try:
                    self.engine.submit(s.device_id, toks, time.time() - self._t0)
                except ConnectionError:
                    # the replica died and the stream could not be re-placed;
                    # the shed sweep below turns it into an explicit loss
                    if s.device_id in self.engine.streams:
                        raise
        finished = []
        traced = telemetry.enabled()
        for v in self.engine.step(time.time() - self._t0) or []:
            s = self._running[v.device_id]
            s._device.on_verdict(v)
            if traced:
                s._trace.append(telemetry.TraceEvent(
                    device_id=v.device_id, round=s._rounds,
                    t=time.time() - self._t0, k=s._last_drafted,
                    n_accepted=int(v.n_accepted), n_commit=len(v.tokens),
                    queue_s=float(v.queue_s), verify_s=float(v.verify_s),
                ))
            s._note_round(v.tokens, n_drafted=s._last_drafted, n_accepted=v.n_accepted)
            if len(s._device.committed) >= s.max_new:
                finished.append(s)
        for s in finished:
            self.engine.retire(s.device_id)
            del self._running[s.device_id]
            s._finish(s._device.committed)
        self._sweep_lost()

    def _sweep_lost(self) -> None:
        """Sessions whose streams were shed with an evicted replica end with
        an explicit rejection (SessionResult.shed) carrying whatever was
        committed before the loss — never a hung serve loop."""
        lost = getattr(self.engine, "lost_devices", None)
        if not lost:
            return
        lost = set(lost)
        for dev in [d for d in self._running if d in lost]:
            s = self._running.pop(dev)
            log.warning("session %d was shed with its replica; ending it", dev)
            s._finish(s._device.committed if s._device is not None else [], shed=True)
        for dev in [d for d in self._waiting if d in lost]:
            s = self._waiting.pop(dev)
            s._finish([], shed=True)

    # -- reference backend ---------------------------------------------------

    def _reference_rounds(self, sessions: List[Session]) -> Iterator[None]:
        """Lock-step draft+verify over the sessions' prompts, emitting
        per-round events; yields once per round so single-session streaming
        stays incremental.  A thin consumer of engine_loop.sled_rounds —
        the ONE copy of the ground-truth loop — so the reference backend can
        never drift from sled_generate."""
        spec = self.spec
        lens = {s.prompt.shape[0] for s in sessions}
        if len(lens) > 1:
            raise ValueError(
                "the reference backend batches sessions lock-step and needs "
                f"equal prompt lengths, got {sorted(lens)}"
            )
        prompts = np.stack([s.prompt for s in sessions])
        budgets = [s.max_new for s in sessions]
        committed: List[List[int]] = [[] for _ in sessions]
        gen = engine_loop.sled_rounds(
            self.models.draft, self.models.draft_params,
            self.models.target, self.models.target_params,
            jnp.asarray(prompts),
            max_new=max(budgets),
            k_max=spec.k_max, c_th=spec.c_th, greedy=spec.greedy,
            seed=0, attn_chunk=spec.attn_chunk, steps=self._reference_jits(),
            kv_dtype=spec.kv_dtype,
        )
        for rnd in gen:
            for b, s in enumerate(sessions):
                if len(committed[b]) >= budgets[b]:
                    continue  # this stream is done; it just rides the batch
                row = [int(t) for t in rnd.tokens[b, : int(rnd.n_commit[b])]]
                committed[b].extend(row)
                s._note_round(
                    row, n_drafted=int(rnd.lengths[b]), n_accepted=int(rnd.n_accepted[b])
                )
                if len(committed[b]) >= budgets[b]:
                    s._finish(committed[b])
            if all(s.done for s in sessions):
                break  # heterogeneous budgets: don't ride out the longest row
            yield None

    def _reference_jits(self) -> dict:
        if self._ref_steps is None:
            spec = self.spec
            self._ref_steps = engine_loop.make_sled_steps(
                self.models.draft, self.models.target,
                k_max=spec.k_max, c_th=spec.c_th, greedy=spec.greedy,
                attn_chunk=spec.attn_chunk,
            )
        return self._ref_steps

    def _reference_stats(self, sessions: List[Session], wall: float) -> EngineStats:
        """SimResult-shaped record for the reference loop (no server)."""
        total = sum(len(s.result.tokens) for s in sessions)
        rounds = max((s.result.rounds for s in sessions), default=0)
        drafted = sum(s.result.drafted for s in sessions)
        accepted = sum(s.result.accepted for s in sessions)
        wall = max(wall, 1e-9)
        return EngineStats(
            wstgr=total / wall,
            per_device_rate=total / max(len(sessions), 1) / wall,
            server_busy_frac=1.0,
            rounds=rounds,
            timeouts=0,
            fallback_tokens=0,
            mean_batch_fill=float(len(sessions)),
            mean_round_latency=0.0,
            server_rounds_per_s=rounds / wall,
            streams_served=len(sessions),
            acceptance_rate=accepted / max(drafted, 1),
        )

    # -- transport backend ---------------------------------------------------

    async def _transport_fleet(self, sessions: List[Session]):
        spec, tspec = self.spec, self.spec.transport
        server = TransportServer(self.engine)

        def net_for(dev: int) -> str:
            rc = spec.class_of(dev)
            return rc.net if rc is not None else tspec.net

        def relink(dev: int):
            # mid-stream reconnect hook: a fresh link of the same flavor
            # (and the device's class net), attached to the server before
            # the client re-Hellos on it
            async def dial():
                fresh = make_link(
                    tspec.link,
                    net=NETS[net_for(dev)],
                    seed=spec.session_seed_base + dev,
                )
                server.attach(fresh.server)
                return fresh.device

            return dial

        runs = []
        for idx, s in enumerate(sessions):
            link = make_link(
                tspec.link,
                net=NETS[net_for(s.device_id)],
                seed=spec.session_seed_base + s.device_id,
            )
            server.attach(link.server)
            client = EdgeClient(
                self.kit_for(s.device_id),
                s.device_id,
                s.prompt,
                link.device,
                max_new=s.max_new,
                max_len=spec.max_len,
                qmode=tspec.qmode,
                pipeline=tspec.pipeline,
                verify_timeout=tspec.verify_timeout,
                admit_timeout=tspec.verify_timeout,
                draft_rate=self.rate_for(s.device_id),
                kctl=spec.kctl,
                cctl=spec.cctl,
                seed=spec.session_seed_base + s.device_id,
                on_round=s._note_round,
                reconnect=relink(s.device_id),
            )
            runs.append((idx, s, client))

        async def run_one(idx: int, s: Session, client: EdgeClient):
            await asyncio.sleep(idx * tspec.stagger_s)
            tokens = await client.run()
            s._trace = client.trace  # client-side attribution incl. wire_s
            s._finish(tokens, client=client.stats)

        await asyncio.gather(*(run_one(i, s, c) for i, s, c in runs))
        for _ in range(500):  # let in-flight Close frames retire their streams
            if not self.engine.streams:
                break
            await asyncio.sleep(0.01)
        stats = server.stats()
        await server.stop()
        fleet = ClientStats.merge([c.stats for _, _, c in runs])
        return stats, fleet
