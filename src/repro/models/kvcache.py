"""KV-cache pytree with speculative-rollback semantics.

The cache buffer index IS the absolute token position, and a per-row
``length`` marks how many entries are committed.  Speculative rollback after
verification never moves data: the server just sets
``length = base + n_accepted (+1 for the corrected/bonus token)`` — entries
past ``length`` are masked out of attention and overwritten by the next
verify round.  SSM states can't be masked retroactively, so SSM layers store
per-position state checkpoints during verification instead (see mamba2.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_kv_cache(
    num_layers: int,
    batch: int,
    max_len: int,
    num_kv_heads: int,
    head_dim: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((num_layers, batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((num_layers, batch, max_len, num_kv_heads, head_dim), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def kv_cache_spec(num_layers, batch, max_len, num_kv_heads, head_dim, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-in (dry-run: no allocation)."""
    return {
        "k": jax.ShapeDtypeStruct((num_layers, batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jax.ShapeDtypeStruct((num_layers, batch, max_len, num_kv_heads, head_dim), dtype),
        "length": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def rollback(cache: Dict[str, jax.Array], new_length: jax.Array) -> Dict[str, jax.Array]:
    """O(1) rollback: commit only ``new_length`` entries per row."""
    return {**cache, "length": new_length.astype(jnp.int32)}
