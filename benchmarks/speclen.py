"""Paper Fig. 5: speculative length vs device throughput & system capacity.

Expected: longer speculative windows LOWER per-device throughput (longer
verification periods slow the response update rate) but RAISE system
capacity (fewer verification rounds per committed token frees the server).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.serving.devices import A100_X4, RPI5
from repro.serving.simulator import SimConfig, capacity, simulate


def run(quick: bool = False) -> list:
    rows = []
    lens = (1, 2, 4, 8, 16) if not quick else (1, 4, 16)
    for k in lens:
        cfg = SimConfig(
            mode="sled", spec_len=k, acceptance=0.90,
            device_rate=RPI5.rate("llama-1b-draft", 4),
            target_params=11e9, server_batch=16, batch_policy="deadline",
            n_devices=8, sim_time=12.0 if quick else 30.0,
        )
        r = simulate(cfg, A100_X4)
        cap = capacity(dataclasses.replace(cfg, sim_time=10.0 if quick else 20.0),
                       A100_X4, n_max=3072)
        rows.append({
            "spec_len": k,
            "device_tok_s": round(r.per_device_rate, 2),
            "capacity": cap,
            "round_latency_ms": round(r.mean_round_latency * 1e3, 1),
        })
    emit(rows, "fig5_speclen")
    return rows


if __name__ == "__main__":
    run()
