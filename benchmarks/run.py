"""Benchmark harness: one module per paper table/figure + roofline/kernels.

Prints ``name,us_per_call,derived`` CSV rows per benchmark.  ``--quick``
shrinks sim horizons for CI; the full run matches EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()

    from benchmarks import availability, capacity, confidence, pareto
    from benchmarks import roofline_bench, speclen, verify_kernel, wstgr

    suites = {
        "availability": availability.run,
        "table1_capacity": capacity.run,
        "fig3_confidence": confidence.run,
        "fig4_wstgr": wstgr.run,
        "fig5_speclen": speclen.run,
        "fig6_pareto": pareto.run,
        "roofline": roofline_bench.run,
        "verify_kernel": verify_kernel.run,
    }
    failures = []
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            fn(quick=args.quick)
            print(f"# {name}: done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"# {name}: FAILED {e!r}", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
