"""``repro tune`` — profiling-driven auto-configuration of a fleet spec.

    repro tune --spec examples/specs/fleet.json --quick --json bench.json \
               --out tuned.json

Profiles a short measured run of the fleet (per-class acceptance, verify
span calibration), sweeps per-class candidates (k, c_th, draft model,
quant bits; placement when there is a replica set) through the calibrated
simulator + Eq. 2 cost model, validates the top candidates on the real
engine, and emits:

  stdout         the sweep narrative + winning per-class configuration
  --out PATH     the winning ServeSpec as a committable JSON artifact
                 (``repro serve --spec PATH --check`` must accept it)
  --json PATH    the full BENCH record: calibration, every scored
                 candidate, real-engine validation rows
"""
from __future__ import annotations

import argparse
import json
from typing import Optional

from repro.api import ServeSpec, SpecError
from repro.serving.devices import SERVERS
from repro.tuning import TuneConfig, tune


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro tune",
        description="Auto-tune a heterogeneous fleet ServeSpec from a "
                    "profiled run (see src/repro/tuning/).",
    )
    ap.add_argument("--spec", type=str, required=True,
                    help="fleet ServeSpec JSON artifact (fleet.classes non-empty)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep axes + shorter probes (CI smoke)")
    ap.add_argument("--server", choices=sorted(SERVERS), default="a100x4",
                    help="ServerProfile for roofline calibration + Eq. 2 cost")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-round deadline seconds (0: derive from the "
                         "profiled round latency)")
    ap.add_argument("--miss-cap", type=float, default=0.1,
                    help="matched deadline-miss rate across candidates")
    ap.add_argument("--validate", type=int, default=2,
                    help="finalists to re-measure on the real engine")
    ap.add_argument("--validate-mult", type=int, default=1,
                    help=">1: rank surviving finalists by throughput with "
                         "the fleet scaled by this factor (stress ranking)")
    ap.add_argument("--json", type=str, default="",
                    help="write the full tuning record as a BENCH artifact")
    ap.add_argument("--out", type=str, default="",
                    help="write the winning ServeSpec JSON here")
    return ap


def main(argv: Optional[list] = None) -> None:
    args = build_parser().parse_args(argv)
    try:
        with open(args.spec) as f:
            spec = ServeSpec.from_json(f.read())
    except OSError as e:
        raise SystemExit(f"cannot read spec {args.spec}: {e}")
    except SpecError as e:
        raise SystemExit(f"invalid ServeSpec: {e}")
    if not spec.fleet.active:
        raise SystemExit(
            f"{args.spec} has no fleet.classes — repro tune configures "
            "heterogeneous fleets (see examples/specs/fleet.json)"
        )
    tcfg = TuneConfig(
        server=args.server,
        deadline_s=args.deadline,
        miss_cap=args.miss_cap,
        n_validate=args.validate,
        validate_mult=args.validate_mult,
        quick=args.quick,
    )
    result = tune(spec, tcfg)
    if args.out:
        with open(args.out, "w") as f:
            f.write(result.winner.to_json_str())
        print(f"wrote winning spec to {args.out} "
              f"(verify: repro serve --spec {args.out} --check)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "tune", "quick": args.quick,
                       **result.to_json()}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
