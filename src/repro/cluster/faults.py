"""Deterministic fault injection + the supervision primitives it exercises.

Three pieces:

* :class:`Backoff` — capped, jittered exponential backoff with a SEEDED
  jitter source, so a supervised respawn schedule is exactly reproducible
  run to run (the chaos harness depends on it);
* :class:`FaultyChannel` — a ControlChannel wrapper the injector arms to
  drop, delay, or flap control-plane RPCs without touching the worker;
* :class:`ChaosInjector` — executes a :class:`~repro.api.spec.FaultSpec`
  schedule against a live Router: each event fires when the Router's step
  counter reaches the event's ``round``, deterministically (kill/hang act
  on the replica; drop/delay/flap arm its FaultyChannel).

Everything here injects failures through the SAME surfaces real failures
use (SIGKILL, severed sockets, erroring RPCs), so recovery code paths
tested under chaos are the ones production faults hit.
"""

from __future__ import annotations

import logging
import random
import time
from typing import List, Optional

from repro.cluster.remote import ControlChannel, ReplicaGone

log = logging.getLogger(__name__)


class Backoff:
    """Capped jittered exponential backoff: base * 2^n, +- jitter, <= cap.

    ``attempt()`` returns the next delay in seconds and advances; ``reset()``
    after a success.  Jitter comes from a dedicated seeded Random so two
    runs of the same chaos schedule sleep identically.
    """

    def __init__(
        self,
        base_s: float = 0.2,
        max_s: float = 5.0,
        jitter: float = 0.1,
        seed: int = 0,
    ):
        if base_s <= 0 or max_s < base_s or not 0.0 <= jitter < 1.0:
            raise ValueError(
                f"bad backoff (base_s={base_s}, max_s={max_s}, jitter={jitter})"
            )
        self.base_s = base_s
        self.max_s = max_s
        self.jitter = jitter
        self.attempts = 0
        self._rng = random.Random(seed)

    def peek(self) -> float:
        delay = min(self.base_s * (2.0 ** self.attempts), self.max_s)
        if self.jitter:
            delay *= 1.0 + self._rng.uniform(-self.jitter, self.jitter)
        return delay

    def attempt(self) -> float:
        delay = self.peek()
        self.attempts += 1
        return delay

    def reset(self) -> None:
        self.attempts = 0


class FaultyChannel:
    """ControlChannel wrapper with armable fault modes (chaos injection).

    Transparent until armed; then the next ``drop_n`` requests raise
    :class:`ReplicaGone` (frame "lost" before the worker sees it), the next
    ``delay_n`` requests stall ``delay_s`` each before forwarding, and
    ``flap()`` severs the link ONCE so exactly one request fails and the
    next reconnect heals — the shape the v4 one-shot retry absorbs.
    ``kill()`` is terminal: every request fails until the channel is
    replaced (what a crashed worker looks like from the dialing side).
    """

    def __init__(self, inner: ControlChannel):
        self.inner = inner
        self.drop_n = 0
        self.delay_n = 0
        self.delay_s = 0.0
        self.killed = False
        self.dropped = 0
        self.delayed = 0

    # -- chaos arms ----------------------------------------------------------

    def arm_drop(self, n: int) -> None:
        self.drop_n += int(n)

    def arm_delay(self, n: int, delay_s: float) -> None:
        self.delay_n += int(n)
        self.delay_s = float(delay_s)

    def flap(self) -> None:
        """One transient failure, then healthy: drop exactly one RPC and
        sever the socket so the retry path has to reconnect."""
        self.drop_n += 1

    def kill(self) -> None:
        self.killed = True
        self.inner.close()

    def hang(self) -> None:
        """Test hook: emulate a silent peer with a huge per-RPC delay."""
        self.delay_n = 1 << 30
        self.delay_s = 3600.0

    # -- ControlChannel surface ----------------------------------------------

    @property
    def address(self) -> str:
        return self.inner.address

    @property
    def timeout(self) -> float:
        return self.inner.timeout

    @property
    def connected(self) -> bool:
        return self.inner.connected

    def next_seq(self) -> int:
        return self.inner.next_seq()

    def connect(self) -> None:
        if self.killed:
            raise ReplicaGone(f"worker at {self.address} is chaos-killed")
        self.inner.connect()

    def reconnect(self) -> None:
        if self.killed:
            raise ReplicaGone(f"worker at {self.address} is chaos-killed")
        self.inner.reconnect()

    def close(self) -> None:
        self.inner.close()

    def request(self, msg, *, timeout: Optional[float] = None):
        if self.killed:
            raise ReplicaGone(f"worker at {self.address} is chaos-killed")
        if self.drop_n > 0:
            self.drop_n -= 1
            self.dropped += 1
            self.inner.close()  # the frame never made it: link looks severed
            raise ReplicaGone(
                f"chaos: control frame to {self.address} dropped "
                f"({type(msg).__name__})"
            )
        if self.delay_n > 0:
            self.delay_n -= 1
            self.delayed += 1
            time.sleep(self.delay_s)
        return self.inner.request(msg, timeout=timeout)


class ChaosInjector:
    """Executes a seeded FaultSpec schedule against a live Router.

    The Router calls :meth:`on_step` with its step counter before every
    cluster step; events whose ``round`` has arrived fire once, in schedule
    order.  Kill/hang act on the replica object (SIGKILL / SIGSTOP for real
    worker processes, channel-level equivalents otherwise); drop/delay/flap
    arm the replica's FaultyChannel — and raise if the channel was never
    wrapped, because a chaos spec that silently does nothing is worse than
    one that fails loudly.
    """

    def __init__(self, fault_spec, router):
        self.spec = fault_spec
        self.router = router
        self.fired: List[tuple] = []  # (round, kind, replica) for reporting
        self._pending = sorted(
            fault_spec.events, key=lambda e: (e.round, e.replica, e.kind)
        )

    @property
    def done(self) -> bool:
        return not self._pending

    def on_step(self, step_no: int) -> None:
        while self._pending and self._pending[0].round <= step_no:
            ev = self._pending.pop(0)
            self._fire(ev, step_no)

    def _fire(self, ev, step_no: int) -> None:
        replica = self.router.replicas[ev.replica]
        log.warning(
            "chaos: firing %s on replica %d at step %d", ev.kind, ev.replica, step_no
        )
        if ev.kind == "kill":
            kill = getattr(replica, "chaos_kill", None)
            if kill is None:
                raise RuntimeError(
                    f"replica {ev.replica} ({type(replica).__name__}) does not "
                    f"support chaos kind 'kill'"
                )
            kill()
        elif ev.kind == "hang":
            hang = getattr(replica, "chaos_hang", None)
            if hang is None:
                raise RuntimeError(
                    f"replica {ev.replica} ({type(replica).__name__}) does not "
                    f"support chaos kind 'hang'"
                )
            hang()
        else:  # drop / delay / flap: needs a FaultyChannel on the link
            chan = getattr(replica, "channel", None)
            if not isinstance(chan, FaultyChannel):
                raise RuntimeError(
                    f"chaos kind {ev.kind!r} targets replica {ev.replica} but its "
                    f"control channel is not a FaultyChannel (build the system "
                    f"with a fault schedule so channels get wrapped)"
                )
            if ev.kind == "drop":
                chan.arm_drop(ev.count)
            elif ev.kind == "delay":
                chan.arm_delay(ev.count, ev.delay_s)
            else:  # flap
                for _ in range(ev.count):
                    chan.flap()
        self.fired.append((step_no, ev.kind, ev.replica))
