"""Per-round trace records and the bounded flight recorder.

A :class:`TraceEvent` is the one round-level record every layer speaks: the
server engine stamps stream id / round seq / queue+verify timings, the
Router adds the serving replica, and an edge client adds its own draft and
wire attribution (rtt minus the server-reported queue+verify is time on the
wire).  Events serialize to plain dicts, so they ride JSON across process
boundaries (codec v3 ``ReplicaStats`` telemetry payloads) and dump as JSONL
(``repro trace``).

The :class:`FlightRecorder` is a bounded ring of the most recent rounds; a
replica keeps one so that crash/eviction/drain reports ("lost_devices")
carry the last N rounds of context rather than nothing.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Iterable, List


@dataclasses.dataclass
class TraceEvent:
    """One resolved round of one stream, with its span breakdown (seconds).

    Fields a given layer cannot know are left at their defaults: the server
    fills ``queue_s``/``verify_s``, only the Router knows ``replica``, and
    only a transport client can measure ``draft_s``/``wire_s``.
    """

    device_id: int
    round: int  # 0-based round seq within the stream
    t: float  # engine/client clock at verdict time (run-relative seconds)
    k: int  # tokens drafted this round
    n_accepted: int
    n_commit: int  # tokens committed (accepted + bonus/correction)
    queue_s: float = 0.0  # admission-queue wait (server-side)
    verify_s: float = 0.0  # verify step wall time (server-side)
    wire_s: float = 0.0  # round-trip minus server time (client-side)
    draft_s: float = 0.0  # device draft time (client-side)
    replica: int = -1  # serving replica index (-1: unknown / single engine)
    fallback: bool = False  # §III-A locally-released round

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "TraceEvent":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


class FlightRecorder:
    """Bounded ring buffer of the most recent :class:`TraceEvent`s."""

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError(f"flight recorder needs capacity >= 1, got {capacity}")
        self.capacity = capacity
        self._ring: Deque[TraceEvent] = deque(maxlen=capacity)

    def record(self, ev: TraceEvent) -> None:
        self._ring.append(ev)

    def extend(self, evs: Iterable[TraceEvent]) -> None:
        self._ring.extend(evs)

    def events(self) -> List[TraceEvent]:
        return list(self._ring)

    def dump(self) -> List[dict]:
        """The ring as JSON-shaped rows, oldest first."""
        return [ev.to_json() for ev in self._ring]

    def clear(self) -> None:
        self._ring.clear()

    def __len__(self) -> int:
        return len(self._ring)
