"""Heterogeneous fleet capacity: tuned per-class configs vs best homogeneous.

The deliverable behind ``repro tune``: on a mixed Jetson/RPi fleet with
rate emulation on (each class throttled to its llama.cpp-measured drafting
tokens/s), the auto-tuned per-class configuration must admit MORE streams
than the best single fleet-wide (k, c_th) configuration at a matched
deadline-miss rate and matched per-class goodput floors.

Capacity here is measured on the REAL serving stack, not the simulator: the
fleet is stepped up by (fractional) multipliers with the verify pool
provisioned to match (``at_multiplier`` — slots = fleet size, so the
serving deadline is what binds, not an admission queue), and a multiplier
counts as admitted only while

  * the trailing deadline-miss rate stays under the cap, and
  * every class still commits >= ``FLOOR_FRAC`` of the per-device rate the
    operator profiled on the base deployment (the Table I "equal response
    rate" requirement — without it, capacity degenerates to "pace every
    device to zero").

Why heterogeneity wins: the slow class cannot afford long drafts (its
throttled draft time eats the per-stream rate floor), while the fast class
NEEDS long drafts (fewer verify rounds per committed token is what holds
the server queue down as the fleet scales).  One fleet-wide (k, c_th) must
betray one side of that tradeoff; per-class configs serve both.

    PYTHONPATH=src python -m benchmarks.fleet --quick --json fleet.json
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import emit
from repro.api import (
    DeviceClassSpec,
    FleetSpec,
    KitCache,
    ModelSpec,
    SchedulerSpec,
    ServeSpec,
    System,
    TransportSpec,
    build_models,
)
from repro.tuning import (
    TuneConfig,
    at_multiplier,
    measured_run,
    tune,
    with_class,
)

# Per-class goodput floor vs the profiled base deployment.  0.75 is the
# "equal response rate" teeth: a fleet-wide c_th=0.0 pushes the noisy slow
# class down to ~0.7x its baseline rate (longer rejected drafts throttle
# its rounds), and a loose floor would let that config buy capacity with
# the slow class's goodput — the exact degeneration Table I forbids.
FLOOR_FRAC = 0.75


def _base_spec(quick: bool) -> ServeSpec:
    """The operator's deployment: 3 Jetson Orin Nano + 3 RPi 4B over
    loopback transport with hardware-rate emulation on.  The per-class
    draft_noise stands in for each board's draft model quality (the RPi's
    noisy draft rarely survives verification; the Jetson's almost always
    does), so per-class (k, c_th) genuinely matter."""
    return ServeSpec(
        backend="transport",
        model=ModelSpec(
            vocab_size=128,
            target_layers=2,
            draft_layers=1,
            draft_noise=0.03,
            seed=0,
        ),
        transport=TransportSpec(
            link="loopback", verify_timeout=30.0, stagger_s=0.0
        ),
        scheduler=SchedulerSpec(
            policy="continuous", slots=4, stagger_ticks=0
        ),
        fleet=FleetSpec(
            classes=(
                DeviceClassSpec(
                    profile="jetson-orin-nano", count=3,
                    draft_model="llama-1b-draft", bits=4,
                    k=4, c_th=0.1, draft_noise=0.02,
                ),
                DeviceClassSpec(
                    profile="rpi4b", count=3,
                    draft_model="llama-1b-draft", bits=4,
                    k=2, c_th=0.4, draft_noise=0.3,
                ),
            ),
            # real throttled drafting: rate_scale compresses wall-clock while
            # preserving the Jetson-vs-RPi ratio (21.0 vs 3.1 tok/s at 4-bit)
            emulate_rates=True,
            rate_scale=20.0,
        ),
        prompt_len=8,
        prompt_seed=2,
        # enough tokens that a k=4 high-acceptance stream still spans 4+
        # verify rounds — the per-session trace-span rate estimator needs
        # round gaps, and 8 tokens at 5/round gives it a single noisy one
        max_new=16 if quick else 24,
        k_max=4,
        c_th=0.3,
    )


def homogeneous_variants(spec: ServeSpec, tcfg: TuneConfig) -> list:
    """Every single fleet-wide (k, c_th) over the tuner's own sweep axes —
    the same hardware mix, one configuration for all of it."""
    out = []
    for k in tcfg.k_choices(spec.k_max):
        for c_th in tcfg.c_th_choices():
            cand = spec
            for i in range(len(spec.fleet.classes)):
                cand = with_class(cand, i, k=k, c_th=c_th)
            out.append((f"homo k={k} c_th={c_th}", cand))
    return out


def measured_capacity(
    spec: ServeSpec,
    *,
    deadline_s: float,
    miss_cap: float,
    base_rates: list,
    m_list,
    models,
    kits,
    first_run: dict = None,
) -> tuple:
    """Real-engine admitted-stream capacity: largest fleet multiplier whose
    measured run holds the miss cap and the per-class goodput floors.
    Fractional multipliers step the fleet a few streams at a time, so two
    configs whose knees differ by less than a fleet-doubling still resolve
    to different capacities.

    No shared step bundle here: compiled VerifySteps are slot-count-shaped
    and every multiplier provisions its own slots, so each measured run
    compiles (and warms) its own — the kit cache is what carries over."""
    cap_streams, cap_m, runs = 0, 0, []
    for i, m in enumerate(m_list):
        scaled = at_multiplier(spec, m)
        # the caller may have already measured the base point (the floors
        # come from it) — reuse it so the floors can't race a re-measure
        # of the very same spec
        if i == 0 and first_run is not None:
            meas = first_run
        else:
            meas = measured_run(
                scaled, deadline_s=deadline_s, models=models, kits=kits,
            )
        floors_ok = all(
            rate >= FLOOR_FRAC * base
            for rate, base in zip(meas["class_rates"], base_rates)
        )
        admitted = meas["deadline_miss_rate"] <= miss_cap and floors_ok
        runs.append(dict(meas, mult=round(m, 3),
                         streams=scaled.fleet.total, admitted=admitted))
        if not admitted:
            break
        cap_streams, cap_m = scaled.fleet.total, round(m, 3)
    return cap_streams, cap_m, runs


def run(quick: bool = False, json_path: str = "") -> list:
    t0 = time.time()
    base = _base_spec(quick)
    tcfg = (TuneConfig(quick=True, n_validate=3, validate_mult=2,
                       rate_floor_frac=FLOOR_FRAC) if quick
            else TuneConfig(n_validate=4, validate_mult=2,
                            rate_floor_frac=FLOOR_FRAC))
    models = build_models(base.model)
    kits = KitCache()

    # one warm system up front populates the kit cache for the base classes;
    # step bundles are slot-count-shaped, so capacity runs compile their own
    warm = System.build(base, models=models, kits=kits)
    warm.warmup()
    warm.serve()

    print(f"[fleet] tuning the base deployment ({base.fleet.total} devices, "
          f"{len(base.fleet.classes)} classes)")
    tres = tune(base, tcfg, models=models, kits=kits)
    deadline_s = tres.deadline_s

    # the admission floors: what the operator's profiled deployment already
    # delivers per class, measured on the same stack every candidate uses
    base_meas = measured_run(
        at_multiplier(base, 1), deadline_s=deadline_s,
        models=models, kits=kits,
    )
    base_rates = base_meas["class_rates"]
    print(f"[fleet] deadline {deadline_s*1e3:.1f} ms, per-class rate floors "
          f"{[round(FLOOR_FRAC * r, 1) for r in base_rates]} tok/s/device")

    # fractional steps: with 3+3 base classes these land on 6, 8, 10, 12,
    # 14, 18, ... streams — fine enough that configs whose knees differ by
    # a few streams get different capacities instead of tying at a doubling
    m_list = ((1, 4 / 3, 5 / 3, 2, 7 / 3, 3) if quick
              else (1, 4 / 3, 5 / 3, 2, 7 / 3, 3, 4, 6))
    candidates = (
        [("baseline-hetero", base)]
        + homogeneous_variants(base, tcfg)
        + [("tuned", tres.winner)]
    )
    rows = []
    for tag, cand in candidates:
        streams, mult, runs = measured_capacity(
            cand, deadline_s=deadline_s, miss_cap=tcfg.miss_cap,
            base_rates=base_rates, m_list=m_list,
            models=models, kits=kits,
            first_run=base_meas if tag == "baseline-hetero" else None,
        )
        admitted_runs = [r for r in runs if r["admitted"]]
        at_cap = admitted_runs[-1] if admitted_runs else runs[0]
        rows.append({
            "config": tag,
            "classes": [
                {"profile": rc.spec.profile, "count": rc.count,
                 "k": rc.k, "c_th": rc.c_th}
                for rc in cand.resolved_classes()
            ],
            "capacity_streams": streams,
            "capacity_mult": mult,
            "deadline_s": deadline_s,
            "miss_at_capacity": at_cap["deadline_miss_rate"],
            "class_rates_at_capacity": at_cap["class_rates"],
            "wstgr_at_capacity": at_cap["wstgr"],
            "runs": runs,
        })
        print(f"[fleet] {tag}: capacity {streams} streams (x{mult}), miss "
              f"{at_cap['deadline_miss_rate']:.1%}, class rates "
              f"{at_cap['class_rates']}")

    homo = [r for r in rows if r["config"].startswith("homo")]
    tuned = next(r for r in rows if r["config"] == "tuned")
    best_homo = max(homo, key=lambda r: (r["capacity_streams"],
                                         r["wstgr_at_capacity"]))
    summary = {
        "section": "summary",
        "tuned_capacity_streams": tuned["capacity_streams"],
        "best_homogeneous": best_homo["config"],
        "best_homogeneous_capacity_streams": best_homo["capacity_streams"],
        "tuned_beats_best_homogeneous": bool(
            tuned["capacity_streams"] > best_homo["capacity_streams"]
        ),
        "miss_cap": tcfg.miss_cap,
        "rate_floor_frac": FLOOR_FRAC,
        "wall_s": round(time.time() - t0, 1),
    }
    rows.append(summary)
    print(f"[fleet] tuned {summary['tuned_capacity_streams']} vs best "
          f"homogeneous ({best_homo['config']}) "
          f"{summary['best_homogeneous_capacity_streams']} admitted streams "
          f"-> tuned_beats_best_homogeneous="
          f"{summary['tuned_beats_best_homogeneous']}")
    emit(rows, "fleet_capacity")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "benchmark": "fleet_capacity", "quick": quick,
                "tune": tres.to_json(), "rows": rows,
            }, f, indent=2)
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", type=str, default="",
                    help="write the rows as a BENCH JSON artifact")
    a = ap.parse_args()
    run(quick=a.quick, json_path=a.json)
