"""Cluster router: replica-sharded verification behind one serving surface.

SLED's capacity story (paper Table I) is one shared target model serving many
heterogeneous drafters; at production scale that target tier is N engine
replicas behind a placement layer, not one engine object.  The
:class:`Router` owns N replicas and turns admission into a placement
decision:

  * **placement** — a pluggable :class:`PlacementPolicy` (BatchPlanner-style
    registry: ``least-loaded`` / ``affinity`` / ``round-robin``) picks the
    replica for each new stream among live replicas with a free pool slot;
  * **migration** — when a stream retires and frees a slot, the router may
    migrate an active stream over from the most-loaded replica
    (``migrate_on_retire``).  A migrated KV row is copied bit-exactly
    (``export_stream``/``import_stream``), so migration never changes a
    stream's tokens — only which replica's batches it rides in;
  * **aggregation** — cluster stats are ``EngineStats.merge`` over live
    replicas, and verdicts carry replica-local queue-depth feedback.

Replicas come in two flavors behind one driver surface:

  :class:`LocalReplica`   — wraps an in-process
      :class:`~repro.core.server_engine.ServerEngine`; fleets share one
      jitted VerifySteps bundle, so N replicas cost one XLA compilation.
  RemoteReplica (cluster/remote.py) — proxies the same surface to a
      ``repro worker`` process over codec v3 control frames; the Router
      steps its remotes CONCURRENTLY on a thread pool (each worker verifies
      in its own process, so cluster throughput scales with processes), and
      a transport failure mid-RPC evicts the replica (``_evict``) rather
      than stalling the fleet.

Migration is flavor-guarded: local<->local moves copy the row in memory;
remote<->remote moves ride ExportStream/ImportStream frames (both workers
rebuilt params from the same spec seed, so the row stays bit-valid); a
MIXED local<->remote move raises :class:`MigrationError`, because the two
sides' parameters have different provenance (in-process object vs
spec-seed rebuild) and bit-identity across the move cannot be verified.

The router mirrors the full ServerEngine driver surface (admit / submit /
step / retire / cancel_request / force_extend / stats / warmup), so the
transport server and the in-process serving loops drive a replica fleet by
holding a Router where they held an engine.
"""

from __future__ import annotations

import logging
from collections.abc import Mapping
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Iterator, List, Optional, Sequence

import jax
import numpy as np

from repro import telemetry
from repro.core.admission import DeviceStream
from repro.core.engine import EngineStats, Verdict
from repro.core.server_engine import ServerEngine

log = logging.getLogger(__name__)


class MigrationError(RuntimeError):
    """A stream move that cannot preserve bit-identity was requested."""


class LocalReplica:
    """In-process replica: a ServerEngine behind the replica driver surface.

    Everything not listed here (admit/submit/step/...) delegates straight to
    the engine; the explicit members are the bits the Router needs uniform
    across flavors (liveness, capacity, fingerprint, lifecycle).
    """

    flavor = "local"

    def __init__(self, engine: ServerEngine):
        self.engine = engine
        self.dead = False

    @property
    def n_free(self) -> int:
        return self.engine.pool.n_free

    @property
    def max_len(self) -> int:
        return self.engine.pool.max_len

    @property
    def fingerprint(self) -> tuple:
        e = self.engine
        return (e.k_max, e.pool.max_len, e.greedy, e.paged_attention)

    def drain(self) -> None:  # lifecycle parity with RemoteReplica
        pass

    def close(self) -> None:
        pass

    def __getattr__(self, name: str):
        return getattr(self.engine, name)


class PlacementPolicy:
    """Chooses the replica for a new stream; None when every pool is full."""

    name = "base"

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        raise NotImplementedError

    @staticmethod
    def _open(router: "Router") -> List[int]:
        return [
            i for i, r in enumerate(router.replicas) if not r.dead and r.n_free > 0
        ]


class LeastLoadedPlacement(PlacementPolicy):
    """Fewest active streams wins (ties break toward the lowest replica id):
    keeps per-replica batch fill even under staggered arrivals."""

    name = "least-loaded"

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        open_ = self._open(router)
        if not open_:
            return None
        return min(open_, key=lambda i: (len(router.replicas[i].streams), i))


class AffinityPlacement(PlacementPolicy):
    """Deterministic device->replica hash (session/cache affinity); falls
    over to least-loaded when the home replica is full or gone."""

    name = "affinity"

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        home = device_id % len(router.replicas)
        r = router.replicas[home]
        if not r.dead and r.n_free > 0:
            return home
        return LeastLoadedPlacement().choose(router, device_id)


class RoundRobinPlacement(PlacementPolicy):
    """Cycle through replicas, skipping full pools and dead replicas."""

    name = "round-robin"

    def __init__(self) -> None:
        self._next = 0

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        n = len(router.replicas)
        for off in range(n):
            i = (self._next + off) % n
            r = router.replicas[i]
            if not r.dead and r.n_free > 0:
                self._next = i + 1
                return i
        return None


PLACEMENT_POLICIES = {
    p.name: p for p in (LeastLoadedPlacement, AffinityPlacement, RoundRobinPlacement)
}


def make_placement(policy: str) -> PlacementPolicy:
    if policy not in PLACEMENT_POLICIES:
        raise ValueError(
            f"unknown placement policy {policy!r} (one of {sorted(PLACEMENT_POLICIES)})"
        )
    return PLACEMENT_POLICIES[policy]()


class _StreamView(Mapping):
    """Read-only dict-like view over every replica's streams.

    Membership and lookup go through the router's placement map (O(1) per
    frame in the transport hot path) instead of merging N dicts per access.
    """

    def __init__(self, router: "Router"):
        self._router = router

    def __contains__(self, device_id) -> bool:
        return device_id in self._router._where

    def __getitem__(self, device_id) -> DeviceStream:
        return self._router._replica(device_id).streams[device_id]

    def __iter__(self) -> Iterator[int]:
        return iter(self._router._where)

    def __len__(self) -> int:
        return len(self._router._where)


class Router:
    """N replicas (local and/or remote) + placement: the cluster object."""

    def __init__(
        self,
        replicas: Sequence[Any],
        *,
        placement: str | PlacementPolicy = "least-loaded",
        migrate_on_retire: bool = True,
    ):
        if not replicas:
            raise ValueError("Router needs at least one replica")
        wrapped = [
            LocalReplica(r) if isinstance(r, ServerEngine) else r for r in replicas
        ]
        k_maxes = {r.k_max for r in wrapped}
        max_lens = {r.max_len for r in wrapped}
        if len(k_maxes) > 1 or len(max_lens) > 1:
            raise ValueError(
                f"replicas must be homogeneous for migration: k_max {k_maxes}, "
                f"max_len {max_lens}"
            )
        self.replicas: List[Any] = wrapped
        self.placement = (
            placement if isinstance(placement, PlacementPolicy) else make_placement(placement)
        )
        self.migrate_on_retire = migrate_on_retire
        self.migrations = 0
        self.evictions = 0
        self.lost_devices: List[int] = []  # streams dropped with evicted replicas
        self._where: Dict[int, int] = {}  # device_id -> replica index
        self._pool: Optional[ThreadPoolExecutor] = None  # remote step fan-out
        # router-side shadow flight recorders, one ring per replica: fed from
        # the verdicts the router itself merges, so a post-mortem survives a
        # worker process that died without answering another RPC
        self.flight: Dict[int, telemetry.FlightRecorder] = {
            i: telemetry.FlightRecorder() for i in range(len(wrapped))
        }
        self.flight_dumps: Dict[int, List[dict]] = {}  # idx -> dump at eviction
        self._round_seq: Dict[int, int] = {}  # device_id -> round seq
        self._last_k: Dict[int, int] = {}  # device_id -> last submitted len

    @classmethod
    def build(
        cls,
        model: Any,
        params: Any,
        *,
        replicas: int,
        n_slots: int,
        placement: str | PlacementPolicy = "least-loaded",
        migrate_on_retire: bool = True,
        **engine_kw,
    ) -> "Router":
        """N homogeneous in-process replicas (``n_slots`` rows each) sharing
        one jitted VerifySteps bundle — the fleet compiles once.  Pass
        ``steps=`` to share an ALREADY-compiled bundle from another
        homogeneous fleet (spec sweeps build every replica count on the same
        executables).  Remote fleets are assembled by repro.api's
        System.build instead (spawn/dial + PlaceReplica, then ``Router``)."""
        if replicas < 1:
            raise ValueError(f"need at least 1 replica, got {replicas}")
        steps = engine_kw.pop("steps", None)
        first = ServerEngine(model, params, n_slots=n_slots, steps=steps, **engine_kw)
        rest = [
            ServerEngine(model, params, n_slots=n_slots, steps=first.steps, **engine_kw)
            for _ in range(replicas - 1)
        ]
        return cls(
            [first, *rest], placement=placement, migrate_on_retire=migrate_on_retire
        )

    # -- introspection -------------------------------------------------------

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def alive(self) -> List[Any]:
        return [r for r in self.replicas if not r.dead]

    @property
    def k_max(self) -> int:
        return self.replicas[0].k_max

    @property
    def paged_attention(self) -> bool:
        return self.replicas[0].paged_attention

    @property
    def streams(self) -> Mapping:
        """Lazy device->stream mapping across replicas (read-only): O(1)
        membership/lookup via the placement map, no per-access dict merge."""
        return _StreamView(self)

    @property
    def queue_depth(self) -> int:
        return sum(r.queue_depth for r in self.alive)

    @property
    def n_free(self) -> int:
        return sum(r.n_free for r in self.alive)

    def replica_of(self, device_id: int) -> int:
        return self._where[device_id]

    def loads(self) -> List[int]:
        """Active stream count per replica (placement test surface)."""
        return [len(r.streams) for r in self.replicas]

    def _replica(self, device_id: int):
        return self.replicas[self._where[device_id]]

    # -- supervision ---------------------------------------------------------

    def _evict(self, idx: int) -> None:
        """A replica's worker is unreachable: mark it dead, record which
        streams went down with it, and keep serving on the survivors.  Side-
        effectful RPCs are never retried (the worker may have half-applied
        them), so eviction is the only safe response to transport failure."""
        replica = self.replicas[idx]
        if replica.dead:
            return
        replica.dead = True
        lost = [d for d, i in self._where.items() if i == idx]
        for d in lost:
            del self._where[d]
        self.lost_devices.extend(lost)
        self.evictions += 1
        # the worker may be gone without a goodbye: dump the router-side
        # shadow ring so the loss report carries the replica's last N rounds
        dump = self.flight[idx].dump()
        self.flight_dumps[idx] = dump
        log.warning(
            "evicting replica %d (%s): lost devices %s; flight recorder "
            "holds %d round(s)",
            idx, getattr(replica, "flavor", "local"), lost, len(dump),
        )
        for row in dump[-8:]:
            log.warning("  flight[replica %d]: %s", idx, row)
        telemetry.count("router_evictions_total")
        replica.close()
        if not self.alive:
            raise RuntimeError(
                f"all {len(self.replicas)} replicas evicted; cluster has no capacity"
            )

    def _guard(self, idx: int):
        """Context for one replica RPC: ReplicaGone -> evict, re-raised so
        the caller can decide whether the operation is retryable."""
        return _EvictOnGone(self, idx)

    # -- admission as placement ----------------------------------------------

    def admit(self, device_id: int, prompt: jax.Array, now: float = 0.0) -> Optional[DeviceStream]:
        """Place the stream on a replica chosen by the policy; None when
        every live replica's pool is full (caller queues and retries on
        retire).  Admission IS retried after an eviction — the worker dying
        before acking means the stream was never placed anywhere."""
        if device_id in self._where:
            raise ValueError(f"device {device_id} already admitted")
        while True:
            idx = self.placement.choose(self, device_id)
            if idx is None:
                return None
            try:
                with telemetry.span("router_place_seconds"):
                    stream = self.replicas[idx].admit(device_id, prompt, now)
            except ConnectionError:
                self._evict(idx)
                continue  # re-place on the survivors
            if stream is None:  # policy raced a concurrent admit; treat as full
                return None
            self._where[device_id] = idx
            log.info(
                "placed device %d on replica %d (%s, %d free slot(s) left)",
                device_id, idx, self.replicas[idx].flavor, self.replicas[idx].n_free,
            )
            return stream

    def retire(self, device_id: int) -> DeviceStream:
        idx = self._where.pop(device_id)
        self._round_seq.pop(device_id, None)
        self._last_k.pop(device_id, None)
        with self._guard(idx):
            stream = self.replicas[idx].retire(device_id)
        if self.migrate_on_retire:
            self._rebalance_into(idx)
        return stream

    def migrate(self, device_id: int, dst: int) -> None:
        """Move a quiescent stream to replica ``dst`` bit-identically: the
        KV row is copied exactly between same-flavor replicas with matching
        fingerprints, so the stream's future tokens are unchanged — only its
        batch-mates are.  Local->local moves share params by object; a
        remote->remote move is valid because both workers rebuilt params
        from the same spec seed.  Mixed flavors raise MigrationError."""
        src = self._where[device_id]
        if src == dst:
            return
        src_r, dst_r = self.replicas[src], self.replicas[dst]
        if dst_r.dead:
            raise MigrationError(f"replica {dst} was evicted; cannot migrate into it")
        if src_r.flavor != dst_r.flavor:
            raise MigrationError(
                f"cannot migrate device {device_id} from {src_r.flavor} replica "
                f"{src} to {dst_r.flavor} replica {dst}: parameters on the two "
                f"sides have different provenance (in-process object vs worker "
                f"spec-seed rebuild), so bit-identity across the move cannot be "
                f"guaranteed"
            )
        if src_r.fingerprint != dst_r.fingerprint:
            raise MigrationError(
                f"replica fingerprints differ ({src_r.fingerprint} vs "
                f"{dst_r.fingerprint}); migration would change the stream's tokens"
            )
        with telemetry.span("router_migrate_seconds"):
            with self._guard(src):
                stream, row = src_r.export_stream(device_id)
            try:
                with self._guard(dst):
                    dst_r.import_stream(stream, row)
            except ConnectionError:
                # dst died mid-import: put the stream back where it came from
                src_r.import_stream(stream, row)
                self._where[device_id] = src
                raise
            except Exception:
                # roll back: the stream must never be lost mid-migration
                src_r.import_stream(stream, row)
                raise
        self._where[device_id] = dst
        self.migrations += 1
        telemetry.count("router_migrations_total")
        log.info("migrated device %d: replica %d -> %d", device_id, src, dst)

    def _rebalance_into(self, dst: int) -> None:
        """After a retirement freed a slot on ``dst``: pull one quiescent
        SAME-FLAVOR stream over from the most-loaded replica when the
        imbalance is ≥2 (moving one stream then strictly improves balance)."""
        dst_r = self.replicas[dst]
        if dst_r.dead or dst_r.n_free == 0:
            return
        loads = self.loads()
        candidates = [
            i
            for i, r in enumerate(self.replicas)
            if i != dst and not r.dead and r.flavor == dst_r.flavor
        ]
        if not candidates:
            return
        src = max(candidates, key=lambda i: (loads[i], -i))
        if loads[src] - loads[dst] < 2:
            return
        replica = self.replicas[src]
        movable = [d for d in replica.streams if not replica.has_inflight(d)]
        if not movable:
            return
        self.migrate(movable[0], dst)

    # -- request path (delegated via placement map) --------------------------

    def submit(
        self,
        device_id: int,
        draft_tokens: np.ndarray,
        now: float,
        draft_q: Optional[np.ndarray] = None,
    ) -> None:
        self._last_k[device_id] = int(np.asarray(draft_tokens).shape[0])
        with self._guard(self._where[device_id]):
            self._replica(device_id).submit(device_id, draft_tokens, now, draft_q=draft_q)

    def cancel_request(self, device_id: int) -> bool:
        with self._guard(self._where[device_id]):
            return self._replica(device_id).cancel_request(device_id)

    def force_extend(self, device_id: int, tokens: np.ndarray) -> int:
        with self._guard(self._where[device_id]):
            return self._replica(device_id).force_extend(device_id, tokens)

    def has_inflight(self, device_id: int) -> bool:
        return device_id in self._where and self._replica(device_id).has_inflight(device_id)

    def next_event_hint(self, now: float) -> Optional[float]:
        hints = [h for r in self.alive if (h := r.next_event_hint(now)) is not None]
        return min(hints) if hints else None

    # -- the serving hot loop ------------------------------------------------

    def step(self, now: float) -> Optional[List[Verdict]]:
        """Step every replica whose policy fires; one merged verdict list.

        Local replicas step back to back in this process (they contend for
        the same accelerator anyway); REMOTE replicas are stepped
        concurrently on a thread pool — each RPC blocks only on its worker's
        verification, so N workers verify in parallel and admitted-stream
        capacity scales with processes.  Verdicts merge in replica order
        regardless of completion order, and each verdict's queue-depth
        feedback stays replica-local — that is the congestion signal for the
        streams riding that replica.  A worker that fails mid-step is
        evicted and the surviving replicas' verdicts are still returned.
        """
        remote_idx = [
            i
            for i, r in enumerate(self.replicas)
            if not r.dead and r.flavor == "remote"
        ]
        futures = {}
        with telemetry.span("router_step_seconds"):
            if len(remote_idx) > 1:
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=len(self.replicas), thread_name_prefix="router-step"
                    )
                futures = {
                    i: self._pool.submit(self.replicas[i].step, now) for i in remote_idx
                }
            results: Dict[int, Optional[List[Verdict]]] = {}
            for i, replica in enumerate(self.replicas):
                if replica.dead or i in futures:
                    continue
                try:
                    results[i] = replica.step(now)
                except ConnectionError:
                    self._evict(i)
            for i, fut in futures.items():
                try:
                    results[i] = fut.result()
                except ConnectionError:
                    self._evict(i)
        verdicts: List[Verdict] = []
        for i in sorted(results):
            out = results[i]
            if not out:
                continue
            ring = self.flight[i]
            for v in out:
                # shadow ring: recorded unconditionally (a deque append per
                # verdict) so eviction post-mortems exist even when metrics
                # collection is off
                seq = self._round_seq.get(v.device_id, 0)
                self._round_seq[v.device_id] = seq + 1
                ring.record(
                    telemetry.TraceEvent(
                        device_id=v.device_id,
                        round=seq,
                        t=now,
                        k=self._last_k.get(v.device_id, 0),
                        n_accepted=v.n_accepted,
                        n_commit=len(v.tokens),
                        queue_s=v.queue_s,
                        verify_s=v.verify_s,
                        replica=i,
                    )
                )
            verdicts.extend(out)
        return verdicts or None

    def warmup(self, buckets=None) -> Dict[int, float]:
        """Warm one local replica (an in-process fleet shares a single
        VerifySteps bundle, so its executables are hot for every sibling)
        plus EVERY remote replica — each worker process has its own compile
        cache, and an un-warmed worker would pay XLA compilation inside its
        first timed step."""
        out: Dict[int, float] = {}
        warmed_local = False
        for r in self.alive:
            if r.flavor == "local":
                if warmed_local:
                    continue
                warmed_local = True
            secs = r.warmup(buckets)
            for k, v in secs.items():
                out[k] = max(out.get(k, 0.0), v)
        return out

    def drain(self) -> None:
        """Ask every remote worker to exit (reaping spawned processes);
        local replicas are no-ops.  Idempotent."""
        for r in self.replicas:
            if not r.dead:
                r.drain()
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    # -- stats ---------------------------------------------------------------

    def stats(self, now: Optional[float] = None) -> EngineStats:
        return EngineStats.merge(self.replica_stats(now))

    def replica_stats(self, now: Optional[float] = None) -> List[EngineStats]:
        out = []
        for i, r in enumerate(self.replicas):
            if r.dead:
                continue
            try:
                out.append(r.stats(now))
            except ConnectionError:
                self._evict(i)
        return out

    def telemetry_payload(self) -> dict:
        """Cluster-level telemetry record, same keys as the single-engine
        ``ServerEngine.telemetry_payload``: this process's metrics snapshot
        plus the shadow flight rings (flattened, each event tagged with its
        replica), with per-remote worker payloads and eviction dumps
        attached when present."""
        if not telemetry.enabled():
            return {}
        flight = [ev.to_json() for ring in self.flight.values() for ev in ring.events()]
        flight.sort(key=lambda e: e["t"])
        out = {"snapshot": telemetry.registry().snapshot(), "flight": flight}
        workers = {
            str(i): r.last_telemetry
            for i, r in enumerate(self.replicas)
            if getattr(r, "last_telemetry", None)
        }
        if workers:
            out["workers"] = workers
        if self.flight_dumps:
            out["evicted"] = {str(i): d for i, d in self.flight_dumps.items()}
        return out


class _EvictOnGone:
    """``with router._guard(idx):`` — evict replica ``idx`` if the body dies
    with a transport failure (ReplicaGone is a ConnectionError), then
    re-raise so the caller sees the loss."""

    def __init__(self, router: Router, idx: int):
        self.router = router
        self.idx = idx

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None and issubclass(exc_type, ConnectionError):
            self.router._evict(self.idx)
        return False
