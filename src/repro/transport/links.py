"""Pluggable edge<->server channels: loopback, simulated, and real sockets.

A link is a pair of :class:`Endpoint` halves (device side, server side); each
half sends and receives whole encoded frames (bytes).  Implementations:

  LoopbackLink   — in-memory queues, zero latency, nothing dropped: the
                   baseline for token-for-token equivalence checks.
  SimulatedLink  — every frame pays serialization (bytes * 8 / bandwidth, a
                   shared per-direction line: back-to-back frames queue behind
                   each other) plus propagation (one-way latency + gaussian
                   jitter), and may be dropped.  Delivery is FIFO per
                   direction — jitter never reorders frames, it only widens
                   gaps — which mirrors a TCP-like transport and keeps the
                   protocol free of sequence-gap handling.
  StreamEndpoint — one half of a REAL byte-stream socket (TCP or UDS):
                   frames ride an asyncio StreamReader/Writer and are
                   reassembled from arbitrary read chunks by the codec's
                   FrameDecoder (the wire format is already length-prefixed).
                   ``tcp_listen``/``tcp_connect`` open localhost-or-beyond
                   endpoint pairs, so client and server can run in separate
                   processes — the ROADMAP "real sockets" slice.

Per-endpoint LinkStats count frames/bytes both ways plus drops, so wire cost
is measurable end-to-end (benchmarks/wstgr.py --transport emits them).
"""
from __future__ import annotations

import asyncio
import dataclasses
import random
from typing import Awaitable, Callable, Optional, Tuple

from repro.serving.devices import NetProfile
from repro.transport.codec import FrameDecoder

_CLOSE = object()  # queue sentinel: peer closed its sending half


@dataclasses.dataclass
class LinkStats:
    frames_tx: int = 0
    bytes_tx: int = 0
    frames_rx: int = 0
    bytes_rx: int = 0
    frames_dropped: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


class Endpoint:
    """One half of a link: ``await send(frame)`` / ``await recv()``.

    ``recv`` returns None once the peer has closed and all in-flight frames
    have drained.  Concrete pipes are installed by the Link constructors.
    """

    def __init__(self):
        self.stats = LinkStats()
        self._out: Optional["_Pipe"] = None
        self._in: Optional["_Pipe"] = None
        self._closed = False

    async def send(self, frame: bytes) -> None:
        if self._closed:
            raise ConnectionError("endpoint is closed")
        self.stats.frames_tx += 1
        self.stats.bytes_tx += len(frame)
        await self._out.put(frame)

    async def recv(self) -> Optional[bytes]:
        frame = await self._in.get()
        if frame is _CLOSE:
            return None
        self.stats.frames_rx += 1
        self.stats.bytes_rx += len(frame)
        return frame

    def close(self) -> None:
        """Close the sending half; the peer's recv() drains then returns None."""
        if not self._closed:
            self._closed = True
            self._out.put_nowait_close()


class _Pipe:
    """Direct queue pipe (loopback): frames appear immediately, in order."""

    def __init__(self):
        self.q: asyncio.Queue = asyncio.Queue()

    async def put(self, frame) -> None:
        self.q.put_nowait(frame)

    def put_nowait_close(self) -> None:
        self.q.put_nowait(_CLOSE)

    async def get(self):
        return await self.q.get()


class _SimPipe(_Pipe):
    """One direction of a simulated link.

    The sender computes each frame's arrival time (line-busy serialization +
    propagation + jitter, monotonically non-decreasing so delivery stays
    FIFO); a forwarder task sleeps until that wall-clock instant and only
    then exposes the frame to the receiver.
    """

    def __init__(self, net: NetProfile, rng: random.Random, stats: LinkStats):
        super().__init__()
        self.net = net
        self.rng = rng
        self.stats = stats
        self._staged: asyncio.Queue = asyncio.Queue()
        self._line_free = 0.0
        self._last_arrival = 0.0
        self._task: Optional[asyncio.Task] = None

    def _ensure_forwarder(self) -> None:
        if self._task is None:
            self._task = asyncio.get_running_loop().create_task(self._forward())

    async def put(self, frame) -> None:
        self._ensure_forwarder()
        if self.rng.random() < self.net.drop_prob:
            self.stats.frames_dropped += 1
            return
        now = asyncio.get_running_loop().time()
        start = max(now, self._line_free)
        self._line_free = start + len(frame) * 8.0 / self.net.bandwidth_bps
        propagation = max(0.0, self.net.one_way + self.rng.gauss(0.0, self.net.rtt_jitter / 2))
        arrival = max(self._line_free + propagation, self._last_arrival)
        self._last_arrival = arrival
        self._staged.put_nowait((arrival, frame))

    def put_nowait_close(self) -> None:
        # the close rides the wire behind any staged frames
        if self._task is None:
            self.q.put_nowait(_CLOSE)
        else:
            self._staged.put_nowait((self._last_arrival, _CLOSE))

    async def _forward(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            arrival, frame = await self._staged.get()
            delay = arrival - loop.time()
            if delay > 0:
                await asyncio.sleep(delay)
            self.q.put_nowait(frame)
            if frame is _CLOSE:
                return


def _wire(a: Endpoint, b: Endpoint, ab: _Pipe, ba: _Pipe) -> None:
    a._out, b._in = ab, ab
    b._out, a._in = ba, ba


class LoopbackLink:
    """Zero-latency, lossless in-memory link."""

    def __init__(self):
        self.device = Endpoint()
        self.server = Endpoint()
        _wire(self.device, self.server, _Pipe(), _Pipe())

    @property
    def endpoints(self) -> Tuple[Endpoint, Endpoint]:
        return self.device, self.server


class SimulatedLink:
    """Link with a NetProfile imposed on every frame, both directions.

    Uplink (device->server) and downlink share the profile but have
    independent lines and jitter streams; ``seed`` makes a run reproducible.
    """

    def __init__(self, net: NetProfile, *, seed: int = 0):
        self.net = net
        self.device = Endpoint()
        self.server = Endpoint()
        up = _SimPipe(net, random.Random(seed * 2 + 1), self.device.stats)
        down = _SimPipe(net, random.Random(seed * 2 + 2), self.server.stats)
        _wire(self.device, self.server, up, down)

    @property
    def endpoints(self) -> Tuple[Endpoint, Endpoint]:
        return self.device, self.server


class StreamEndpoint(Endpoint):
    """Endpoint over a real asyncio byte stream (TCP / unix socket).

    ``send`` writes the already-encoded frame to the socket; ``recv`` feeds
    read chunks into a :class:`~repro.transport.codec.FrameDecoder` and pops
    complete frames — the codec's length-prefixed header does the stream
    reassembly, so arbitrary TCP segmentation (half a header here, three
    frames there) never splits or merges a message.
    """

    def __init__(self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter):
        super().__init__()
        self._reader = reader
        self._writer = writer
        self._decoder = FrameDecoder()

    async def send(self, frame: bytes) -> None:
        if self._closed:
            raise ConnectionError("endpoint is closed")
        self.stats.frames_tx += 1
        self.stats.bytes_tx += len(frame)
        self._writer.write(frame)
        await self._writer.drain()

    async def recv(self) -> Optional[bytes]:
        while True:
            frame = self._decoder.next_raw()
            if frame is not None:
                self.stats.frames_rx += 1
                self.stats.bytes_rx += len(frame)
                return frame
            data = await self._reader.read(65536)
            if not data:  # peer closed; trailing partial frames are dropped
                return None
            self._decoder.feed(data)

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._writer.close()


async def tcp_listen(
    on_endpoint: Callable[[StreamEndpoint], Awaitable[None] | None],
    host: str = "127.0.0.1",
    port: int = 0,
) -> Tuple[asyncio.AbstractServer, int]:
    """Listen for frame-stream connections; ``on_endpoint`` is called with a
    StreamEndpoint per accepted socket (e.g. TransportServer.attach).
    Returns ``(server, bound_port)`` — port 0 picks a free one."""

    async def handle(reader: asyncio.StreamReader, writer: asyncio.StreamWriter) -> None:
        result = on_endpoint(StreamEndpoint(reader, writer))
        if asyncio.iscoroutine(result):
            await result

    server = await asyncio.start_server(handle, host, port)
    bound = server.sockets[0].getsockname()[1]
    return server, bound


async def tcp_connect(host: str, port: int) -> StreamEndpoint:
    """Device-side half of a TCP link (server side comes from tcp_listen)."""
    reader, writer = await asyncio.open_connection(host, port)
    return StreamEndpoint(reader, writer)


def parse_addr(addr: str):
    """Parse a listen/dial address into ``("tcp", host, port)`` or
    ``("uds", path)``.

    Accepted forms: ``tcp:HOST:PORT`` (port 0 = pick a free one),
    ``uds:/path/to.sock``, and bare ``HOST:PORT`` as a tcp shorthand.
    """
    if addr.startswith("uds:"):
        path = addr[len("uds:"):]
        if not path:
            raise ValueError(f"uds address needs a socket path: {addr!r}")
        return ("uds", path)
    rest = addr[len("tcp:"):] if addr.startswith("tcp:") else addr
    host, sep, port_s = rest.rpartition(":")
    if not sep or not host:
        raise ValueError(
            f"bad address {addr!r} (want tcp:HOST:PORT or uds:/path.sock)"
        )
    try:
        port = int(port_s)
    except ValueError:
        raise ValueError(f"bad port in address {addr!r}") from None
    return ("tcp", host, port)


async def listen_addr(
    on_endpoint: Callable[[StreamEndpoint], Awaitable[None] | None],
    addr: str,
) -> Tuple[asyncio.AbstractServer, str]:
    """Listen on a ``tcp:``/``uds:`` address; returns ``(server, resolved)``
    where ``resolved`` has any port-0 replaced by the bound port."""
    parsed = parse_addr(addr)
    if parsed[0] == "uds":
        async def handle(reader, writer):
            result = on_endpoint(StreamEndpoint(reader, writer))
            if asyncio.iscoroutine(result):
                await result

        server = await asyncio.start_unix_server(handle, path=parsed[1])
        return server, f"uds:{parsed[1]}"
    _, host, port = parsed
    server, bound = await tcp_listen(on_endpoint, host, port)
    return server, f"tcp:{host}:{bound}"


async def connect_addr(addr: str) -> StreamEndpoint:
    """Dial a ``tcp:``/``uds:`` address; the other half of listen_addr."""
    parsed = parse_addr(addr)
    if parsed[0] == "uds":
        reader, writer = await asyncio.open_unix_connection(parsed[1])
        return StreamEndpoint(reader, writer)
    return await tcp_connect(parsed[1], parsed[2])


def make_link(kind: str, net: Optional[NetProfile] = None, *, seed: int = 0):
    """Factory: ``loopback`` or ``sim`` (requires a NetProfile).  TCP links
    are connection-oriented — open them with tcp_listen/tcp_connect."""
    if kind == "loopback":
        return LoopbackLink()
    if kind == "sim":
        if net is None:
            raise ValueError("sim links need a NetProfile (serving/devices.py NETS)")
        return SimulatedLink(net, seed=seed)
    raise ValueError(
        f"unknown link kind {kind!r} (loopback | sim; tcp endpoints come from "
        f"tcp_listen/tcp_connect)"
    )
