"""HLO-text cost model with while-loop trip-count multipliers.

Why not ``compiled.cost_analysis()``: XLA's analysis counts each while BODY
once — a scan-over-88-layers (or an 8x grad-accumulation loop) reports 1/88
(1/8) of the real FLOPs/bytes.  Our models put everything in loops
deliberately (compile time), so we walk the optimized HLO ourselves:

  * computations are parsed into (opcode, result shapes, operand refs);
  * a call graph (while/fusion/call/conditional) propagates execution
    multipliers; while trip counts come from ``known_trip_count`` backend
    configs (XLA annotates scan-derived loops);
  * FLOPs: 2 * numel(result) * prod(contracting dims) per dot (exact for
    matmul-dominated models; convs are counted via their FLOPs estimate);
  * bytes: per top-level op, operands + result — with TPU-style in-place
    semantics for dynamic-update-slice / scatter / dynamic-slice (charged at
    update/slice size, not full-operand size, matching what a real TPU
    executable does to HBM; XLA:CPU's own numbers double-charge these).

This feeds the three-term roofline in analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "call",
    "conditional", "custom-call",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_text: str
    operands: List[str]
    line: str

    @property
    def result_shapes(self):
        return _shape_list(self.result_text)

    @property
    def result_bytes(self) -> int:
        return _bytes_of(self.result_shapes)


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\]\{\},\d]+)\s+([\w\-]+)\((.*?)\)"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(\([^{]*\))?\s*->.*\{\s*$")


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Op]] = {}
        self.param_shapes: Dict[str, Dict[str, str]] = {}
        self.entry: Optional[str] = None
        self._fkind_cache: Dict = {}
        self._parse(hlo_text)
        self._build_multipliers()

    # -- parsing ------------------------------------------------------------

    def _parse(self, text: str) -> None:
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None or not line.startswith(" "):
                m = _COMP_RE.match(line)
                if m and line.endswith("{"):
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                    continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, rtype, opcode, args = m.groups()
            operands = [self._operand_name(a) for a in self._split_args(args)]
            self.comps[cur].append(Op(name, opcode, rtype, operands, line))

    @staticmethod
    def _operand_name(arg: str) -> str:
        """Extract the operand reference from one argument string.

        Post-optimization HLO prints operands typed — ``f32[2,4]{1,0}
        %name`` — so the reference is the last %-prefixed token; bare
        ``%name`` / ``name`` forms (older printers) fall through unchanged.
        Without this, operand byte lookups silently miss and every
        dynamic-update-slice/scatter falls back to "charge the whole
        buffer", burying the in-place semantics this model exists to apply.
        """
        for tok in reversed(arg.split()):
            if tok.startswith("%"):
                return tok.lstrip("%")
        return arg.lstrip("%")

    @staticmethod
    def _split_args(args: str) -> List[str]:
        out, depth, cur = [], 0, []
        for ch in args:
            if ch == "," and depth == 0:
                out.append("".join(cur))
                cur = []
            else:
                depth += ch in "([{"
                depth -= ch in ")]}"
                cur.append(ch)
        if cur:
            out.append("".join(cur))
        return [a for a in (s.strip() for s in out) if a]

    # -- call graph & multipliers --------------------------------------------

    def _build_multipliers(self) -> None:
        self.mult: Dict[str, float] = {c: 0.0 for c in self.comps}
        # computations embedded in a fused op never touch HBM themselves:
        # count their FLOPs but not their bytes
        self.embedded: Dict[str, bool] = {c: False for c in self.comps}
        if self.entry is None and self.comps:
            self.entry = next(iter(self.comps))
        if self.entry is None:
            return
        self.mult[self.entry] = 1.0
        # iterate to fixpoint (call graph is a DAG; few passes suffice)
        for _ in range(32):
            changed = False
            for cname, ops in self.comps.items():
                base = self.mult.get(cname, 0.0)
                if base == 0.0:
                    continue
                for op in ops:
                    for callee, m, emb in self._callees(op):
                        if callee in self.mult:
                            new = base * m
                            emb = emb or self.embedded[cname]
                            if new > self.mult[callee] or (
                                emb != self.embedded[callee] and emb
                            ):
                                self.mult[callee] = max(new, self.mult[callee])
                                self.embedded[callee] = self.embedded[callee] or emb
                                changed = True
            if not changed:
                break

    @staticmethod
    def _callees(op: Op) -> List[Tuple[str, float, bool]]:
        """(callee, multiplier, embedded-in-fused-op)."""
        out = []
        if op.opcode == "while":
            trip = 1.0
            tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.line)
            if tm:
                trip = float(tm.group(1))
            bm = re.search(r"body=%?([\w\.\-]+)", op.line)
            cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
            if bm:
                out.append((bm.group(1), trip, False))
            if cm:
                out.append((cm.group(1), trip + 1, False))
        elif op.opcode in ("fusion", "reduce", "map", "scatter",
                           "reduce-window", "sort", "select-and-scatter"):
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", op.line):
                out.append((m.group(1), 1.0, True))
        elif op.opcode == "call":
            for m in re.finditer(r"to_apply=%?([\w\.\-]+)", op.line):
                out.append((m.group(1), 1.0, False))
        elif op.opcode == "conditional":
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w\.\-]+)|false_computation=%?([\w\.\-]+))", op.line):
                for g in m.groups():
                    if g:
                        for nm in g.split(","):
                            out.append((nm.strip().lstrip("%"), 1.0, False))
        return out

    # -- costs ----------------------------------------------------------------

    _ARTIFACT_OPS = {
        "convert", "bitcast", "transpose", "copy", "reshape", "broadcast",
        "parameter", "constant", "tuple", "get-tuple-element", "iota",
        "compare", "select", "concatenate", "pad", "add", "subtract",
        "multiply", "divide", "maximum", "minimum", "exponential", "negate",
    }

    def _fusion_kind(self, op: Op) -> str:
        """Classify a fusion by its callee's interior: dus | scatter |
        gather | slice | artifact | compute.  'artifact' = pure layout/
        precision plumbing (bf16->f32 upcasts, transposed copies for CPU dot
        layouts) that a TPU executable wouldn't materialise; 'gather' is
        split from 'slice' because a random-access row gather materialises
        its result (charged), while a contiguous slice window is charged at
        the consumer."""
        m = re.search(r"calls=%?([\w\.\-]+)", op.line)
        callee = m.group(1) if m else None
        key = (op.name, callee)
        cached = self._fkind_cache.get(key)
        if cached is not None:
            return cached[0]
        kind = "compute"
        callee_ops = self.comps.get(callee, [])
        inner = {o.opcode for o in callee_ops}
        if "dynamic-update-slice" in inner:
            kind = "dus"
        elif "scatter" in inner:
            kind = "scatter"
        elif "gather" in inner:
            kind = "gather"
        elif inner & {"dynamic-slice", "slice"}:
            kind = "slice"
        elif inner and inner <= self._ARTIFACT_OPS and not (
            inner & {"dot", "reduce", "convolution"}
        ):
            # only cheap elementwise/layout ops inside: a precision/layout hop
            kind = "artifact"
        self._fkind_cache[key] = (kind, self._storage_factor(callee_ops))
        return kind

    # dtypes a cache/weight window is stored as (vs s32/u32/pred index
    # plumbing, whose converts must not be mistaken for the storage hop)
    _STORAGE_DTYPES = {"bf16", "f16", "f32", "f64", "s8", "u8",
                       "f8e4m3fn", "f8e5m2", "s4", "u4"}

    def _storage_factor(self, callee_ops: List[Op]) -> float:
        """Width factor for a fused storage->compute dtype hop: consumers
        stream the window at its STORAGE width (bf16->f32 halves, int8->f32
        quarters).  XLA:CPU emulates narrow dtypes with widened buffers plus
        convert round-trips (f32 -> bf16 -> f32), so the storage width is
        the narrowest storage dtype any convert in the fusion touches;
        converts on s32/pred index plumbing are ignored."""
        if not callee_ops:
            return 1.0
        root = callee_ops[-1].result_shapes  # ROOT is the last op parsed
        if not root:
            return 1.0
        root_w = _DTYPE_BYTES.get(root[0][0], 4)
        by_name = {o.name: o for o in callee_ops}
        widths = []
        for o in callee_ops:
            if o.opcode != "convert" or not o.operands:
                continue
            sides = [o.result_shapes]
            src_op = by_name.get(o.operands[0])
            if src_op is not None:
                sides.append(src_op.result_shapes)
            for shapes in sides:
                if shapes and shapes[0][0] in self._STORAGE_DTYPES:
                    widths.append(_DTYPE_BYTES.get(shapes[0][0], 4))
        if not widths or not root_w:
            return 1.0
        return min(min(widths), root_w) / root_w

    def _update_bytes(self, op: Op, table: Dict[str, int]) -> int:
        """Size of the in-place update window(s) of a dus/scatter (op or
        fusion-wrapped).  HLO fixes the operand order — dynamic-update-slice
        (operand, update, starts...), scatter(operands..., indices,
        updates...) — so the update is positional, never "the smallest
        operand" (start indices are scalars and would always win a min).
        Fusions may loop-fuse SEVERAL updates (e.g. a per-row append unroll
        lands as one fusion with B inner dus ops): all windows are summed."""
        def from_inner(o: Op, t: Dict[str, int]) -> int:
            if o.opcode == "dynamic-update-slice" and len(o.operands) > 1:
                return t.get(o.operands[1], 0)
            if o.opcode == "scatter" and len(o.operands) >= 3:
                n = (len(o.operands) - 1) // 2  # N operands, indices, N updates
                return sum(t.get(u, 0) for u in o.operands[-n:])
            return 0

        if op.opcode in ("dynamic-update-slice", "scatter"):
            upd = from_inner(op, table)
            return upd if upd else op.result_bytes
        m = re.search(r"calls=%?([\w\.\-]+)", op.line)
        callee = m.group(1) if m else None
        if callee in self.comps:
            inner_table = self._symbol_bytes(callee)
            total = sum(from_inner(o, inner_table) for o in self.comps[callee])
            if total:
                return total
        return op.result_bytes

    def _fusion_convert_factor(self, op: Op) -> float:
        self._fusion_kind(op)
        m = re.search(r"calls=%?([\w\.\-]+)", op.line)
        callee = m.group(1) if m else None
        return self._fkind_cache.get((op.name, callee), ("", 1.0))[1]

    def _is_artifact(self, op: Op) -> bool:
        if op.opcode in ("convert", "bitcast", "reshape", "transpose", "copy"):
            return True
        if op.opcode == "fusion":
            return self._fusion_kind(op) == "artifact"
        return False

    def _is_artifact_call(self, op: Op) -> bool:
        """A ``call`` whose interior is pure layout/precision plumbing (e.g.
        an outlined int8-dequant: convert+multiply) — consumers stream the
        original storage, not the widened call result."""
        if op.opcode != "call":
            return False
        m = re.search(r"to_apply=%?([\w\.\-]+)", op.line)
        inner = self.comps.get(m.group(1) if m else "", [])
        return bool(inner) and all(
            o.opcode in self._ARTIFACT_OPS or self._is_artifact(o) for o in inner
        )

    def _symbol_bytes(self, cname: str) -> Dict[str, int]:
        table: Dict[str, int] = {}
        for op in self.comps[cname]:
            if (self._is_artifact(op) or self._is_artifact_call(op)) and op.operands:
                # passthrough: consumers of an upcast/copy read the original
                src = table.get(op.operands[0], op.result_bytes)
                table[op.name] = min(src, op.result_bytes)
            elif op.opcode == "fusion" and self._fusion_kind(op) in ("slice", "gather"):
                # fused slice/gather(+convert): consumers read the window at
                # its storage width (the dtype hop is a CPU-backend artifact
                # — TPU streams the cache at its storage dtype, so an int8
                # cache read through an int8->f32 convert charges 1/4)
                table[op.name] = int(op.result_bytes * self._fusion_convert_factor(op))
            else:
                table[op.name] = op.result_bytes
        return table

    def _symbol_shapes(self, cname: str) -> Dict[str, List[Tuple[str, List[int]]]]:
        return {op.name: op.result_shapes for op in self.comps[cname]}

    def _op_bytes(self, op: Op, table: Dict[str, int]) -> float:
        oc = op.opcode
        if oc in _NO_TRAFFIC or oc.endswith("-done"):
            return 0.0
        if self._is_artifact(op):
            return 0.0
        operand_bytes = [table.get(o, 0) for o in op.operands]
        res = op.result_bytes
        fkind = self._fusion_kind(op) if oc == "fusion" else ""
        if oc == "dynamic-update-slice" or fkind == "dus":
            # in-place on TPU: read+write the update window, not the buffer
            return 2.0 * self._update_bytes(op, table)
        if oc == "scatter" or fkind == "scatter":
            # indices+update read, window write (in-place)
            return 3.0 * self._update_bytes(op, table)
        if oc in ("dynamic-slice", "slice") or fkind == "slice":
            # pure data movement on a contiguous window: the CONSUMER is
            # charged for reading the slice (symbol-table passthrough), so
            # charging here too would double/triple-count weight streams
            # through slice->convert->dot chains
            return 0.0
        if oc == "gather" or fkind == "gather":
            # random access: table touch + result write, at the storage
            # width when the fusion folded a dtype hop into the gather
            rb = res
            if fkind == "gather":
                rb = int(rb * self._fusion_convert_factor(op))
            return 2.0 * rb
        if oc == "broadcast":
            return 2.0 * res
        return float(sum(operand_bytes) + res)

    def _dot_flops(self, op: Op, shapes) -> float:
        if op.opcode not in ("dot", "convolution"):
            return 0.0
        res = op.result_shapes
        numel = 0
        for _, dims in res:
            n = 1
            for d in dims:
                n *= d
            numel += n
        if op.opcode == "convolution":
            # our models lower convs as shifted adds; any residual conv op is
            # negligible — charge 2*numel(out) as a floor
            return 2.0 * numel
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
        lhs = shapes.get(op.operands[0], [])
        if not m or not lhs:
            return 2.0 * numel
        cdims = [int(x) for x in m.group(1).split(",") if x]
        _, ldims = lhs[0]
        k = 1
        for i in cdims:
            if i < len(ldims):
                k *= ldims[i]
        return 2.0 * numel * k

    def totals(self) -> Dict[str, float]:
        flops = 0.0
        bytes_ = 0.0
        coll_bytes = 0.0
        coll_by_kind: Dict[str, float] = {}
        for cname, ops in self.comps.items():
            mult = self.mult.get(cname, 0.0)
            if mult == 0.0:
                continue
            embedded = self.embedded.get(cname, False)
            table = self._symbol_bytes(cname)
            shapes = self._symbol_shapes(cname)
            for op in ops:
                flops += mult * self._dot_flops(op, shapes)
                if not embedded:
                    bytes_ += mult * self._op_bytes(op, table)
                for kind in _COLLECTIVES:
                    if op.opcode == kind or op.opcode == kind + "-start":
                        b = self._collective_bytes(op, table)
                        coll_bytes += mult * b
                        coll_by_kind[kind] = coll_by_kind.get(kind, 0.0) + mult * b
                        break
        return {
            "flops": flops,
            "bytes": bytes_,
            "collective_bytes": coll_bytes,
            "collective_by_kind": coll_by_kind,
        }

    def _collective_bytes(self, op: Op, table: Dict[str, int]) -> float:
        g = 2
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.line)
        if m:
            g = int(m.group(2))
        else:
            m = re.search(r"replica_groups=\{\{([\d,]+)\}", op.line)
            if m:
                g = len(m.group(1).split(","))
        out_b = op.result_bytes
        kind = op.opcode.replace("-start", "")
        if kind == "all-reduce":
            return 2.0 * out_b * (g - 1) / g
        if kind == "all-gather":
            return out_b * (g - 1) / g
        if kind == "reduce-scatter":
            return out_b * (g - 1)
        if kind == "all-to-all":
            return out_b * (g - 1) / g
        return float(out_b)  # collective-permute
