"""Simulate a SLED service area through the public API: an edge fleet over
simulated WLAN links, verified by a 2-replica cluster server.

    PYTHONPATH=src python examples/edge_serving_sim.py

One ServeSpec declares the whole deployment — the real wire protocol pays
NetProfile latency/jitter per frame, clients pipeline draft-ahead under the
round trip, and the router places streams across engine replicas.  (The
paper's discrete-event cost-model tables live in benchmarks/capacity.py.)
"""
from repro.api import ClusterSpec, ModelSpec, ServeSpec, System, TransportSpec

spec = ServeSpec(
    backend="transport",
    model=ModelSpec(vocab_size=128, target_layers=2, draft_noise=0.05),
    transport=TransportSpec(link="sim", net="wlan", stagger_s=0.1),
    cluster=ClusterSpec(replicas=2),
    devices=4, prompt_len=8, max_new=12,
)


def main() -> None:
    result = System.build(spec).serve()
    st = result.engine
    print(f"served {st.streams_served} streams over simulated "
          f"{spec.transport.net}: {result.total_tokens} tokens in "
          f"{st.rounds} rounds, acceptance {st.acceptance_rate:.2f}")
    print(f"wire: {st.bytes_rx} B up / {st.bytes_tx} B down, "
          f"pipeline {result.clients.pipeline_hits} hits")
    for s in result.sessions:
        print(f"  device {s.device_id}: {len(s.tokens)} tokens, "
              f"{s.rounds} rounds, acceptance {s.acceptance_rate:.2f}")


if __name__ == "__main__":
    main()
