"""SLED wire protocol: versioned, length-prefixed binary frames.

Every frame is ``header || payload`` with an 8-byte header::

    magic "SL" (2) | version u8 | msg_type u8 | payload_len u32 (big-endian)

so frames survive byte-stream transports (TCP-style reassembly via
``FrameDecoder``) as well as message-oriented links.  All multi-byte integers
are big-endian; token vectors are little-endian int32 arrays (numpy
``tobytes`` of the natural serving dtype) behind a u16 count.

The draft-probability payload of a ``DraftPacket`` (the q(token) row needed
for lossless sampling-mode verification) dominates frame size at fp32, so it
can ride the wire quantized — ``qmode``:

    "none"  no q payload (greedy verification)
    "f32"   4 bytes/token, exact
    "f16"   2 bytes/token
    "int8"  1 byte/token + one fp32 scale (reuses quant/quantize.py's
            symmetric per-row scheme)

Quantization is an honest wire cost/fidelity trade the benchmarks measure;
decode returns fp32 either way.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Iterator, List, Optional, Union

import numpy as np

from repro.quant.quantize import QTensor, dequantize, quantize

MAGIC = b"SL"
VERSION = 2  # v2: Verdict carries accept_rate + queue_depth feedback
_HEADER = struct.Struct(">2sBBI")
HEADER_SIZE = _HEADER.size
MAX_PAYLOAD = 1 << 20  # sanity cap: no protocol message approaches 1 MiB

# message type ids (wire-stable: append only)
T_HELLO = 1
T_ADMIT = 2
T_DRAFT = 3
T_VERDICT = 4
T_FALLBACK = 5
T_FALLBACK_ACK = 6
T_CLOSE = 7

QMODES = ("none", "f32", "f16", "int8")


class CodecError(ValueError):
    """Malformed, truncated, or version-incompatible frame."""


@dataclasses.dataclass(frozen=True)
class Hello:
    """Device -> server admission request; prompt is prefilled server-side."""

    device_id: int
    prompt: np.ndarray  # (P,) int32


@dataclasses.dataclass(frozen=True)
class Admit:
    """Server -> device admission verdict (ok=False: pool full, wait)."""

    device_id: int
    ok: bool
    slot: int = 0


@dataclasses.dataclass(frozen=True)
class DraftPacket:
    """Device -> server: one drafting round's proposal."""

    device_id: int
    seq: int
    tokens: np.ndarray  # (k,) int32
    draft_q: Optional[np.ndarray] = None  # (k,) fp32 (decoded), or None
    qmode: str = "none"


@dataclasses.dataclass(frozen=True)
class Verdict:
    """Server -> device: verification outcome for DraftPacket ``seq``.

    ``accept_rate`` (this round's draft-acceptance ratio — per-round so the
    control loop reacts to regime shifts; smoothing is the receiver's job)
    and ``queue_depth`` (the serving replica's planner queue after dispatch)
    are the v2 closed-loop feedback fields: devices feed them to an AIMD
    spec-length controller (serving/speclen.py) to tune ``k`` online.
    """

    device_id: int
    seq: int
    n_accepted: int
    tokens: np.ndarray  # committed this round (accepted + correction/bonus)
    next_prev: int
    flags: int = 0  # reserved for future protocol bits (always 0 in v2)
    accept_rate: float = 0.0  # this round's accepted/drafted, in [0, 1]
    queue_depth: int = 0  # replica queue depth after this round's dispatch


@dataclasses.dataclass(frozen=True)
class Fallback:
    """Device -> server: round ``seq`` timed out device-side; the device
    released ``tokens`` locally (§III-A) and asks the server to resync."""

    device_id: int
    seq: int
    tokens: np.ndarray  # (k,) int32 locally-released draft tokens


@dataclasses.dataclass(frozen=True)
class FallbackAck:
    """Server -> device: resync applied; draft from ``next_prev``."""

    device_id: int
    seq: int
    next_prev: int


@dataclasses.dataclass(frozen=True)
class Close:
    """Either side: stream ends; server frees the slot."""

    device_id: int


Message = Union[Hello, Admit, DraftPacket, Verdict, Fallback, FallbackAck, Close]


# -- primitive encoders ------------------------------------------------------


def _put_tokens(out: List[bytes], toks: np.ndarray) -> None:
    toks = np.ascontiguousarray(np.asarray(toks, dtype="<i4"))
    if toks.ndim != 1:
        raise CodecError(f"token vector must be 1-D, got shape {toks.shape}")
    if toks.shape[0] > 0xFFFF:
        raise CodecError(f"token vector too long: {toks.shape[0]}")
    out.append(struct.pack(">H", toks.shape[0]))
    out.append(toks.tobytes())


class _Reader:
    """Bounds-checked cursor over a payload; raises CodecError on overrun."""

    def __init__(self, buf: bytes):
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.buf):
            raise CodecError(
                f"truncated payload: need {n} bytes at offset {self.pos}, "
                f"have {len(self.buf) - self.pos}"
            )
        b = self.buf[self.pos : self.pos + n]
        self.pos += n
        return b

    def u8(self) -> int:
        return self.take(1)[0]

    def u16(self) -> int:
        return struct.unpack(">H", self.take(2))[0]

    def u32(self) -> int:
        return struct.unpack(">I", self.take(4))[0]

    def i32(self) -> int:
        return struct.unpack(">i", self.take(4))[0]

    def f32(self) -> float:
        return struct.unpack(">f", self.take(4))[0]

    def tokens(self) -> np.ndarray:
        n = self.u16()
        return np.frombuffer(self.take(4 * n), dtype="<i4").astype(np.int32)

    def done(self) -> None:
        if self.pos != len(self.buf):
            raise CodecError(f"{len(self.buf) - self.pos} trailing bytes in payload")


# -- q payload (quantized probability row) -----------------------------------


def _encode_q(out: List[bytes], q: Optional[np.ndarray], qmode: str) -> None:
    if qmode not in QMODES:
        raise CodecError(f"unknown qmode {qmode!r}")
    out.append(bytes([QMODES.index(qmode)]))
    if qmode == "none":
        return
    if q is None:
        raise CodecError(f"qmode {qmode!r} requires a draft_q payload")
    q = np.asarray(q, np.float32).reshape(-1)
    out.append(struct.pack(">H", q.shape[0]))
    if qmode == "f32":
        out.append(q.astype("<f4").tobytes())
    elif qmode == "f16":
        out.append(q.astype("<f2").tobytes())
    else:  # int8: symmetric per-row scheme from quant/quantize.py
        qt = quantize(q[None, :], bits=8)
        out.append(struct.pack(">f", float(qt.scale[0, 0])))
        out.append(np.ascontiguousarray(qt.q[0]).astype("|i1").tobytes())


def _decode_q(r: _Reader):
    mode_id = r.u8()
    if mode_id >= len(QMODES):
        raise CodecError(f"unknown qmode id {mode_id}")
    qmode = QMODES[mode_id]
    if qmode == "none":
        return None, qmode
    n = r.u16()
    if qmode == "f32":
        q = np.frombuffer(r.take(4 * n), dtype="<f4").astype(np.float32)
    elif qmode == "f16":
        q = np.frombuffer(r.take(2 * n), dtype="<f2").astype(np.float32)
    else:
        scale = r.f32()
        raw = np.frombuffer(r.take(n), dtype="|i1")
        qt = QTensor(
            q=raw[None, :], scale=np.asarray([[scale]], np.float32), bits=8, shape=(1, n)
        )
        q = np.asarray(dequantize(qt, np.float32))[0]
    return q, qmode


# -- frame encode/decode -----------------------------------------------------


def encode_frame(msg: Message) -> bytes:
    out: List[bytes] = []
    if isinstance(msg, Hello):
        mtype = T_HELLO
        out.append(struct.pack(">I", msg.device_id))
        _put_tokens(out, msg.prompt)
    elif isinstance(msg, Admit):
        mtype = T_ADMIT
        out.append(struct.pack(">IBI", msg.device_id, int(msg.ok), msg.slot))
    elif isinstance(msg, DraftPacket):
        mtype = T_DRAFT
        out.append(struct.pack(">II", msg.device_id, msg.seq))
        _put_tokens(out, msg.tokens)
        _encode_q(out, msg.draft_q, msg.qmode)
    elif isinstance(msg, Verdict):
        mtype = T_VERDICT
        out.append(
            struct.pack(
                ">IIHiBfH",
                msg.device_id,
                msg.seq,
                msg.n_accepted,
                msg.next_prev,
                msg.flags,
                float(msg.accept_rate),
                min(int(msg.queue_depth), 0xFFFF),
            )
        )
        _put_tokens(out, msg.tokens)
    elif isinstance(msg, Fallback):
        mtype = T_FALLBACK
        out.append(struct.pack(">II", msg.device_id, msg.seq))
        _put_tokens(out, msg.tokens)
    elif isinstance(msg, FallbackAck):
        mtype = T_FALLBACK_ACK
        out.append(struct.pack(">IIi", msg.device_id, msg.seq, msg.next_prev))
    elif isinstance(msg, Close):
        mtype = T_CLOSE
        out.append(struct.pack(">I", msg.device_id))
    else:
        raise CodecError(f"cannot encode {type(msg).__name__}")
    payload = b"".join(out)
    return _HEADER.pack(MAGIC, VERSION, mtype, len(payload)) + payload


def decode_frame(buf: bytes) -> tuple:
    """Decode one frame from the head of ``buf``; returns (message, consumed).

    Raises CodecError on a malformed header or payload; an *incomplete* frame
    (fewer bytes than the header announces) also raises — stream transports
    should use FrameDecoder, which buffers instead.
    """
    if len(buf) < HEADER_SIZE:
        raise CodecError(f"truncated header: {len(buf)} < {HEADER_SIZE} bytes")
    magic, version, mtype, plen = _HEADER.unpack_from(buf)
    if magic != MAGIC:
        raise CodecError(f"bad magic {magic!r}")
    if version != VERSION:
        raise CodecError(f"unsupported protocol version {version} (speak {VERSION})")
    if plen > MAX_PAYLOAD:
        raise CodecError(f"payload length {plen} exceeds cap {MAX_PAYLOAD}")
    if len(buf) < HEADER_SIZE + plen:
        raise CodecError(
            f"truncated frame: payload needs {plen} bytes, have {len(buf) - HEADER_SIZE}"
        )
    r = _Reader(bytes(buf[HEADER_SIZE : HEADER_SIZE + plen]))
    if mtype == T_HELLO:
        msg: Message = Hello(device_id=r.u32(), prompt=r.tokens())
    elif mtype == T_ADMIT:
        msg = Admit(device_id=r.u32(), ok=bool(r.u8()), slot=r.u32())
    elif mtype == T_DRAFT:
        dev, seq = r.u32(), r.u32()
        toks = r.tokens()
        q, qmode = _decode_q(r)
        if q is not None and q.shape[0] != toks.shape[0]:
            raise CodecError(f"draft_q length {q.shape[0]} != token count {toks.shape[0]}")
        msg = DraftPacket(device_id=dev, seq=seq, tokens=toks, draft_q=q, qmode=qmode)
    elif mtype == T_VERDICT:
        dev, seq, n_acc, nxt, flags = r.u32(), r.u32(), r.u16(), r.i32(), r.u8()
        accept_rate, queue_depth = r.f32(), r.u16()
        msg = Verdict(
            device_id=dev,
            seq=seq,
            n_accepted=n_acc,
            tokens=r.tokens(),
            next_prev=nxt,
            flags=flags,
            accept_rate=accept_rate,
            queue_depth=queue_depth,
        )
    elif mtype == T_FALLBACK:
        msg = Fallback(device_id=r.u32(), seq=r.u32(), tokens=r.tokens())
    elif mtype == T_FALLBACK_ACK:
        msg = FallbackAck(device_id=r.u32(), seq=r.u32(), next_prev=r.i32())
    elif mtype == T_CLOSE:
        msg = Close(device_id=r.u32())
    else:
        raise CodecError(f"unknown message type {mtype}")
    r.done()
    return msg, HEADER_SIZE + plen


class FrameDecoder:
    """Incremental decoder for byte-stream transports: feed arbitrary chunks,
    iterate complete messages (partial frames wait for more bytes)."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> None:
        self._buf.extend(data)

    def next_raw(self) -> Optional[bytes]:
        """Pop the next COMPLETE frame as raw bytes (header+payload), or None
        when more bytes are needed.  Used by byte-stream endpoints
        (transport/links.py StreamEndpoint) that forward whole frames without
        decoding them; corrupt headers raise the precise CodecError."""
        if len(self._buf) < HEADER_SIZE:
            return None
        magic, version, _, plen = _HEADER.unpack_from(self._buf)
        if magic != MAGIC or version != VERSION or plen > MAX_PAYLOAD:
            decode_frame(bytes(self._buf))  # raises the precise error
        if len(self._buf) < HEADER_SIZE + plen:
            return None
        raw = bytes(self._buf[: HEADER_SIZE + plen])
        del self._buf[: HEADER_SIZE + plen]
        return raw

    def __iter__(self) -> Iterator[Message]:
        while True:
            if len(self._buf) < HEADER_SIZE:
                return
            magic, version, _, plen = _HEADER.unpack_from(self._buf)
            if magic != MAGIC or version != VERSION or plen > MAX_PAYLOAD:
                # corrupt stream: decode_frame raises the precise error
                decode_frame(bytes(self._buf))
            if len(self._buf) < HEADER_SIZE + plen:
                return
            msg, used = decode_frame(bytes(self._buf[: HEADER_SIZE + plen]))
            del self._buf[:used]
            yield msg
