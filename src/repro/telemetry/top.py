"""``repro top`` and ``repro trace``: fleet observability front-ends.

``repro top`` is a live refreshing table over the cluster control plane: it
dials each worker's control socket with its OWN :class:`ControlChannel`
(never sharing a Router's blocking socket), polls ``StatsRequest``, and
renders per-replica fill, acceptance, p50/p95 round latency, and the
speculation-length histogram from the telemetry payload riding codec v3
``ReplicaStats`` frames.  The last-seen payload is kept per replica, so when
a worker dies mid-poll its flight-recorder rows — the last N rounds it
served — are printed as a post-mortem instead of silently disappearing.

``repro trace`` runs a spec with telemetry forced on and dumps the per-round
:class:`~repro.telemetry.trace.TraceEvent` records as JSONL (one event per
line, globally time-ordered), plus an optional Prometheus text exposition.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import threading
import time
from typing import List, Optional

from repro import telemetry

_SPARK = "▁▂▃▄▅▆▇█"


def _sparkline(per_bucket: List[int]) -> str:
    top = max(per_bucket) if per_bucket else 0
    if top <= 0:
        return "-"
    return "".join(_SPARK[min(len(_SPARK) - 1, (c * len(_SPARK)) // (top + 1))]
                   for c in per_bucket)


def _hist(payload: Optional[dict], name: str) -> Optional[dict]:
    if not payload:
        return None
    return (payload.get("snapshot") or {}).get("histograms", {}).get(name)


def _gauge(payload: Optional[dict], name: str) -> Optional[float]:
    if not payload:
        return None
    return (payload.get("snapshot") or {}).get("gauges", {}).get(name)


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.0f}{unit}" if unit == "B" else f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}GiB"


def _per_bucket(h: dict) -> List[int]:
    """De-cumulate snapshot bucket rows ([[le, cum], ...]) into raw counts."""
    prev, out = 0, []
    for _, cum in h.get("buckets", []):
        out.append(int(cum) - prev)
        prev = int(cum)
    return out


class ReplicaView:
    """Last-seen state of one worker: stats + telemetry survive the worker."""

    def __init__(self, idx: int, address: str):
        self.idx = idx
        self.address = address
        self.channel = None
        self.stats: Optional[dict] = None
        self.telemetry: Optional[dict] = None
        self.alive = False
        self.error = ""

    def poll(self) -> None:
        from repro.cluster.remote import ControlChannel, ReplicaGone, WorkerError
        from repro.transport import codec

        try:
            if self.channel is None:
                self.channel = ControlChannel(self.address, timeout=5.0)
            reply = self.channel.request(codec.StatsRequest(now=0.0, has_now=False))
            self.stats = json.loads(reply.stats_json)
            if reply.telemetry_json:
                self.telemetry = json.loads(reply.telemetry_json)
            self.alive = True
            self.error = ""
        except WorkerError as e:  # alive, but e.g. no engine placed yet
            self.alive = True
            self.error = str(e)
        except (ReplicaGone, OSError) as e:
            self.alive = False
            self.error = str(e)
            self.channel = None

    def row(self) -> str:
        addr = self.address if len(self.address) <= 34 else "…" + self.address[-33:]
        if not self.alive:
            return f"{self.idx:<3} {addr:<34} {'LOST':<5} {self.error[:40]}"
        if self.stats is None:
            return f"{self.idx:<3} {addr:<34} {'up':<5} ({self.error or 'no stats yet'})"
        st = self.stats
        lat = _hist(self.telemetry, "engine_round_latency_seconds")
        p50 = f"{lat['p50'] * 1e3:7.2f}" if lat else "      -"
        p95 = f"{lat['p95'] * 1e3:7.2f}" if lat else "      -"
        kh = _hist(self.telemetry, "engine_k")
        spark = _sparkline(_per_bucket(kh)) if kh else "-"
        # adaptive confidence controllers publish cctl_c_th; fixed -> "-"
        ch = _hist(self.telemetry, "cctl_c_th")
        cspark = _sparkline(_per_bucket(ch)) if ch else "-"
        # KV-pool capacity gauges (int8 pools show ~half the bytes/slot)
        pool_b = _gauge(self.telemetry, "engine_kv_pool_bytes")
        slot_b = _gauge(self.telemetry, "engine_bytes_per_slot")
        pool = _fmt_bytes(pool_b) if pool_b else "-"
        bslot = _fmt_bytes(slot_b) if slot_b else "-"
        return (
            f"{self.idx:<3} {addr:<34} {'up':<5} "
            f"{st.get('streams_served', 0):>6} {st.get('rounds', 0):>7} "
            f"{st.get('mean_batch_fill', 0.0):>5.2f} "
            f"{st.get('acceptance_rate', 0.0):>6.3f} "
            f"{bslot:>8} {pool:>8} {p50} {p95}  {spark:<9} {cspark}"
        )


_HEADER = (
    f"{'ID':<3} {'ADDRESS':<34} {'STATE':<5} "
    f"{'SERVED':>6} {'ROUNDS':>7} {'FILL':>5} {'ACCEPT':>6} "
    f"{'B/SLOT':>8} {'POOL':>8} "
    f"{'p50ms':>7} {'p95ms':>7}  {'K':<9} C_TH"
)


def render(views: List[ReplicaView], flight: int) -> str:
    lines = ["repro top — fleet control-plane poll", _HEADER]
    lines += [v.row() for v in views]
    for v in views:
        if v.alive or not v.telemetry:
            continue
        rows = (v.telemetry.get("flight") or [])[-flight:]
        if not rows:
            continue
        lines.append(f"-- replica {v.idx} lost: last {len(rows)} rounds "
                     f"from its flight recorder --")
        for ev in rows:
            lines.append(
                f"   dev={ev.get('device_id')} round={ev.get('round')} "
                f"k={ev.get('k')} acc={ev.get('n_accepted')} "
                f"commit={ev.get('n_commit')} queue={ev.get('queue_s', 0.0):.4f}s "
                f"verify={ev.get('verify_s', 0.0):.4f}s"
                + (" FALLBACK" if ev.get("fallback") else "")
            )
    return "\n".join(lines)


def _spec_addresses(spec) -> List[str]:
    return [r.address for r in spec.cluster.replica_specs
            if r.flavor == "remote" and r.address]


def _load_spec(path: str):
    from repro.api.spec import ServeSpec

    with open(path) as f:
        return ServeSpec.from_json(f.read())


def _start_demo(spec) -> tuple:
    """Build the spec's fleet (spawning its workers), drive serve() rounds in
    a daemon thread for load, and return (system, worker addresses)."""
    from repro.api.system import System

    spec = dataclasses.replace(spec, telemetry=True)
    system = System.build(spec)
    addrs = [r.address for r in system.engine.replicas
             if getattr(r, "flavor", "local") == "remote"]
    stop = threading.Event()

    def serve_loop():
        try:
            system.warmup()
            while not stop.is_set():
                system.serve()
        except Exception:
            pass  # demo load only; the table keeps polling regardless

    thread = threading.Thread(target=serve_loop, daemon=True)
    thread.start()
    return system, addrs, stop, thread


def main_top(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro top",
        description="Live fleet table over worker control sockets.",
    )
    ap.add_argument("--connect", action="append", default=[],
                    help="worker control address to poll (repeatable)")
    ap.add_argument("--spec", type=str, default="",
                    help="ServeSpec JSON: poll its remote replicas' addresses")
    ap.add_argument("--demo", action="store_true",
                    help="with --spec: spawn the fleet and drive load while topping")
    ap.add_argument("--interval", type=float, default=1.0)
    ap.add_argument("--iterations", type=int, default=0,
                    help="stop after N refreshes (0 = until interrupted)")
    ap.add_argument("--no-clear", action="store_true",
                    help="append refreshes instead of clearing the screen (CI)")
    ap.add_argument("--flight", type=int, default=8,
                    help="flight-recorder rows shown for a lost replica")
    args = ap.parse_args(argv)

    system = stop = demo_thread = None
    addresses = list(args.connect)
    if args.spec:
        spec = _load_spec(args.spec)
        if args.demo:
            system, demo_addrs, stop, demo_thread = _start_demo(spec)
            addresses += demo_addrs
        else:
            addresses += _spec_addresses(spec)
    if not addresses:
        ap.error("nothing to poll: pass --connect ADDR, or --spec with remote "
                 "replica addresses (or --spec ... --demo to spawn a fleet)")

    views = [ReplicaView(i, a) for i, a in enumerate(addresses)]
    try:
        n = 0
        while True:
            for v in views:
                v.poll()
            frame = render(views, flight=args.flight)
            if not args.no_clear:
                print("\x1b[2J\x1b[H", end="")
            print(frame, flush=True)
            n += 1
            if args.iterations and n >= args.iterations:
                break
            time.sleep(args.interval)
    except KeyboardInterrupt:
        pass
    finally:
        if stop is not None:
            stop.set()
        if demo_thread is not None:
            # let the in-flight serve pass finish — tearing down the runtime
            # under a live jit compile aborts the process
            demo_thread.join(timeout=60.0)
        if system is not None:
            system.close()


def main_trace(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro trace",
        description="Run a spec with telemetry on; dump per-round trace JSONL.",
    )
    ap.add_argument("--spec", type=str, default="",
                    help="ServeSpec JSON (default: the built-in engine spec)")
    ap.add_argument("--out", type=str, default="trace.jsonl")
    ap.add_argument("--exposition", type=str, nargs="?", const="-", default="",
                    help="also emit the Prometheus text exposition "
                         "(to PATH, or stdout when given bare)")
    args = ap.parse_args(argv)

    from repro.api.spec import ServeSpec
    from repro.api.system import System

    spec = _load_spec(args.spec) if args.spec else ServeSpec(backend="engine")
    spec = dataclasses.replace(spec, telemetry=True)
    system = System.build(spec)
    try:
        system.warmup()
        result = system.serve()
    finally:
        system.close()
    rows = sorted((ev.to_json() for ev in result.trace),
                  key=lambda e: (e["t"], e["device_id"], e["round"]))
    with open(args.out, "w") as f:
        for row in rows:
            f.write(json.dumps(row) + "\n")
    # parse-check the snapshot round trip before reporting success
    snapshot = json.loads(json.dumps(telemetry.registry().snapshot()))
    if args.exposition == "-":
        print(telemetry.registry().exposition(), end="")
    elif args.exposition:
        with open(args.exposition, "w") as f:
            f.write(telemetry.registry().exposition())
    devices = sorted({r["device_id"] for r in rows})
    print(f"wrote {len(rows)} trace events for {len(devices)} devices -> {args.out}")
    print(f"registry: {len(snapshot['counters'])} counters, "
          f"{len(snapshot['gauges'])} gauges, "
          f"{len(snapshot['histograms'])} histograms"
          + (f"; exposition -> {args.exposition}"
             if args.exposition and args.exposition != "-" else ""))


if __name__ == "__main__":
    main_top()
