"""Checkpointing: sharded-safe save/restore with elastic re-sharding.

Format: one directory per step —
    step_000123/
      manifest.json   (step, flat key list, shapes/dtypes, mesh shape, extras)
      arrays.npz      (flattened pytree, '/'-joined keys)

Design points for scale (documented vs. implemented):
  * restore never requires the SAME mesh: arrays are saved unsharded here
    (single-process container) and ``device_put`` with the *target* sharding
    on load — elastic scaling = same call with a different mesh;
  * saves run on a background thread (training never blocks on disk);
  * ``keep_last`` garbage-collects old steps; a partially written step is
    never selected by ``latest_step`` because the manifest is written last;
  * on a multi-host deployment the same layout becomes one ``arrays-{proc}``
    file per process holding addressable shards — the manifest already
    records shapes/dtypes so the reader is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np


def _flatten(tree: Any):
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype == ml_dtypes.bfloat16:  # npz has no bf16: store raw bits
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat, dtypes


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    extras: Optional[Dict[str, Any]] = None,
    keep_last: int = 3,
    async_save: bool = False,
) -> threading.Thread | None:
    """Write a checkpoint. With async_save=True returns the writer thread."""
    flat, dtypes = _flatten(tree)  # host copy on the caller thread (safe point)

    def _write():
        d = os.path.join(ckpt_dir, f"step_{step:09d}")
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": dtypes,
            "extras": extras or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)  # manifest+rename last => never a torn checkpoint
        _gc(ckpt_dir, keep_last)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    tree_like: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``tree_like`` (values or SDS specs).

    ``shardings``: optional matching pytree of NamedSharding — this is the
    elastic-rescale path: restoring onto a different mesh just means passing
    that mesh's shardings here.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    out = []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = arrays[key]
        if manifest["dtypes"].get(key) == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        val = jnp.asarray(arr, dtype=dtype)
        if shard_leaves is not None:
            val = jax.device_put(val, shard_leaves[i])
        out.append(val)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extras"]
