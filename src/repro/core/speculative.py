"""Batched lossless speculative verification (Leviathan et al. 2023), SLED-style.

Alignment invariant (see core/verification.py):
  the server feeds ``tokens_in = [prev_committed_token, d_1 .. d_K]`` and the
  target model returns ``logits[i] = p(. | context, tokens_in[:i+1])`` — so
  ``logits[i]`` is the distribution that judges draft ``d_{i+1}``, and
  ``logits[m]`` provides the correction/bonus distribution after ``m``
  accepted drafts.

Variable-length drafts (SLED's dynamic drafting sends whatever the
confidence threshold allowed) are handled with per-row ``lengths`` masks —
the batch is padded to K_max by the server's batch planner, exactly the
paper's "applies appropriate padding to equalize token lengths".

Modes:
  greedy=True   — acceptance is argmax-equality; exactly lossless and needs
                  only token ids on the wire (the SLED edge deployment mode).
  greedy=False  — Leviathan rejection sampling. Exact residual sampling needs
                  the draft distribution at the rejected position
                  (``draft_q_full``); without it we fall back to sampling the
                  correction from the target distribution (documented
                  deviation — see DESIGN.md §3 changed-assumptions table).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

PAD_TOKEN = -1


@dataclasses.dataclass
class VerifyResult:
    n_accepted: jax.Array  # (B,) accepted draft count m in [0, K]
    n_commit: jax.Array    # (B,) committed new tokens = m + 1
    out_tokens: jax.Array  # (B, K+1): d_1..d_m, extra, PAD...
    extra_token: jax.Array  # (B,) correction (rejected) or bonus (all accepted)
    accepted_mask: jax.Array  # (B, K)
    rejected: jax.Array    # (B,) True if a draft was rejected (m < length)


jax.tree_util.register_dataclass(
    VerifyResult,
    data_fields=["n_accepted", "n_commit", "out_tokens", "extra_token",
                 "accepted_mask", "rejected"],
    meta_fields=[],
)


def speculative_verify(
    draft_tokens: jax.Array,   # (B, K) int32 (padded with anything past length)
    target_logits: jax.Array,  # (B, K+1, V) fp32
    key: jax.Array,
    *,
    lengths: Optional[jax.Array] = None,  # (B,) in [0, K]; None -> all K
    draft_q: Optional[jax.Array] = None,  # (B, K) q(d_i) from the draft model
    draft_q_full: Optional[jax.Array] = None,  # (B, K, V) full draft dists
    temperature: float = 1.0,
    greedy: bool = False,
) -> VerifyResult:
    B, K = draft_tokens.shape
    V = target_logits.shape[-1]
    if lengths is None:
        lengths = jnp.full((B,), K, jnp.int32)
    lengths = lengths.astype(jnp.int32)
    b_idx = jnp.arange(B)

    if greedy:
        tgt_choice = jnp.argmax(target_logits[:, :K], axis=-1)  # (B, K)
        accept = tgt_choice == draft_tokens
    else:
        assert draft_q is not None, "sampling mode needs draft token probabilities"
        logp = jax.nn.log_softmax(target_logits[:, :K] / temperature, axis=-1)
        p_sel = jnp.exp(jnp.take_along_axis(logp, draft_tokens[..., None], axis=-1))[..., 0]
        k_acc, key = jax.random.split(key)
        u = jax.random.uniform(k_acc, (B, K))
        accept = u < p_sel / jnp.maximum(draft_q, 1e-20)

    valid = jnp.arange(K)[None, :] < lengths[:, None]
    accept = accept & valid
    # first failure = acceptance count m (positions past length auto-fail)
    fail = ~accept
    m = jnp.where(fail.any(axis=1), jnp.argmax(fail, axis=1), K).astype(jnp.int32)
    rejected = m < lengths

    extra_logits = target_logits[b_idx, m]  # (B, V)
    if greedy:
        extra = jnp.argmax(extra_logits, axis=-1).astype(draft_tokens.dtype)
    else:
        p_m = jax.nn.softmax(extra_logits / temperature, axis=-1)
        if draft_q_full is not None:
            q_m = draft_q_full[b_idx, jnp.minimum(m, K - 1)]
            resid = jnp.maximum(p_m - q_m, 0.0)
            rs = resid.sum(-1, keepdims=True)
            resid = jnp.where(rs > 1e-9, resid / jnp.maximum(rs, 1e-9), p_m)
            dist = jnp.where(rejected[:, None], resid, p_m)
        else:
            dist = p_m  # target-fallback residual (approximate; see module doc)
        k_extra, key = jax.random.split(key)
        extra = jax.random.categorical(
            k_extra, jnp.log(jnp.maximum(dist, 1e-30))
        ).astype(draft_tokens.dtype)

    # committed tokens: accepted drafts, then the extra token, then PAD
    pos = jnp.arange(K + 1)[None, :]
    drafts_p1 = jnp.pad(draft_tokens, ((0, 0), (0, 1)))
    out = jnp.where(pos < m[:, None], drafts_p1, PAD_TOKEN)
    out = jnp.where(pos == m[:, None], extra[:, None], out)

    return VerifyResult(
        n_accepted=m,
        n_commit=m + 1,
        out_tokens=out,
        extra_token=extra,
        accepted_mask=accept,
        rejected=rejected,
    )


def sample_token(logits: jax.Array, key: jax.Array, temperature: float = 1.0,
                 greedy: bool = False):
    """Sample (token, prob-of-token, full-dist) from (B, V) logits."""
    probs = jax.nn.softmax(logits.astype(jnp.float32) / max(temperature, 1e-6), axis=-1)
    if greedy or temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1)
    else:
        tok = jax.random.categorical(key, logits.astype(jnp.float32) / temperature, axis=-1)
    p = jnp.take_along_axis(probs, tok[..., None], axis=-1)[..., 0]
    return tok.astype(jnp.int32), p, probs
