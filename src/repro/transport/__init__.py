"""Async edge<->server transport runtime (wire protocol + links + loops).

Decouples edge devices from the verification server behind an explicit,
versioned wire protocol so network effects — RTT, jitter, bandwidth,
stragglers, timeout fallback — are real runtime behaviour instead of
simulator-only abstractions:

  codec.py   — length-prefixed binary frames (DraftPacket / Verdict /
               admission + fallback control) with optional fp16/int8
               quantization of the draft-probability payload; v2 Verdicts
               carry acceptance + queue-depth feedback for adaptive k; v3
               adds the Router<->worker control plane (PlaceReplica with a
               serialized ServeSpec, per-RPC driver frames, bit-exact
               StreamState/KV-row export+import, ReplicaStats, Drain)
  links.py   — channel abstraction: zero-latency loopback, a SimulatedLink
               imposing per-NetProfile latency/bandwidth/jitter/drop on
               every frame, and StreamEndpoint over real TCP/UDS sockets
               (tcp_listen / tcp_connect / listen_addr / connect_addr)
  server.py  — asyncio TransportServer fronting a ServerEngine or a
               cluster Router of N replicas (same serving surface)
  client.py  — asyncio EdgeClient: pipelined draft-ahead device loop with
               optional closed-loop AIMD spec-length control
  worker.py  — repro worker entry point: ONE engine replica per OS process
               behind a TCP/UDS control socket, driven by a cluster
               Router's RemoteReplica (cluster/remote.py)
"""

from repro.transport.codec import (
    Admit,
    Close,
    CodecError,
    DraftPacket,
    Fallback,
    FallbackAck,
    FrameDecoder,
    Hello,
    Verdict,
    decode_frame,
    encode_frame,
)
from repro.transport.links import (
    LinkStats,
    LoopbackLink,
    SimulatedLink,
    StreamEndpoint,
    connect_addr,
    listen_addr,
    make_link,
    parse_addr,
    tcp_connect,
    tcp_listen,
)

__all__ = [
    "Admit",
    "Close",
    "CodecError",
    "DraftPacket",
    "Fallback",
    "FallbackAck",
    "FrameDecoder",
    "Hello",
    "Verdict",
    "decode_frame",
    "encode_frame",
    "LinkStats",
    "LoopbackLink",
    "SimulatedLink",
    "StreamEndpoint",
    "connect_addr",
    "listen_addr",
    "make_link",
    "parse_addr",
    "tcp_connect",
    "tcp_listen",
]
